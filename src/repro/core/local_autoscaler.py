"""Local autoscaler — Algorithm 1 (batch-size autoscaling).

Online control of an instance's max batch size from local backpressure; no
offline profiling. If backpressure >= 1 the batch size is halved; otherwise
it grows by an EWMA-weighted proportional step:

    bs <- alpha * (1/bp) * bs + (1 - alpha) * bs

As bp -> 1 the growth slows, converging to the largest batch size that
meets the ITL SLO without a throughput regression (paper Fig. 11/12).
A growth-factor cap (default 2x/update) bounds the proportional term when
backpressure is near zero — an implementation guard, the fixed point is
unchanged.

Reproduction note (recorded in EXPERIMENTS.md §Repro-claims): Algorithm 1
as literally printed is unstable — at any throughput steady state
TBP = thr_prev/thr_curr = 1, which takes the "else" branch and halves the
batch size; the halving lowers throughput, so TBP stays > 1 and the batch
size collapses to 1. The paper's own description ("if TBP > 1, no
throughput gain is observed from INCREASING the batch size") implies TBP
judges growth steps, so we (a) evaluate TBP only when the previous action
increased the batch size, and (b) treat bp == 1 as the fixed point (no
change). With this reading the controller converges to the Fig. 3
inflection exactly as Fig. 11/12 report.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

from repro.core.backpressure import LocalMetrics, local_backpressure


@dataclass
class LocalAutoscaler:
    itl_slo: float                      # overridden per-update by resident min
    alpha: float = 0.5                  # EWMA smoothing factor (paper value)
    min_batch: int = 1
    max_batch: int = 4096
    init_batch: int = 8
    max_growth: float = 2.0             # cap on per-update growth factor

    # AIMD-style stabilization: remember the batch size that violated and
    # regrow toward (not past) it; relax the ceiling slowly so the
    # controller stays adaptive to workload drift. Without this the 2x
    # regrow jumps back over sharp inflections (KV preemption cliffs) and
    # the controller limit-cycles instead of converging (Fig. 11/12 show
    # flat converged lines).
    ceiling_shrink: float = 0.95
    ceiling_relax: float = 1.02
    # graduated decrease: halving is right for gross violations (the paper's
    # case: ITL 2x over SLO), but a 5-15% throughput dip just past the
    # inflection only needs a proportional step back (floored at
    # mild_decrease) — halving there reopens the gap the controller just
    # closed and produces sawtooth batch sizes.
    mild_violation: float = 1.25
    mild_decrease: float = 0.9
    # EWMA on the throughput input to TBP (ROADMAP robustness item): the
    # raw metric is sampled at control-tick grain, where one sequence
    # finishing just before vs. just after the tick flips TBP across 1 and
    # different engines/sampling grains converge to different batch-size
    # ceilings. Smoothing the *input* keeps Algorithm 1 itself unchanged
    # (alpha_thr=1 reproduces the raw-sample behaviour exactly) while
    # making its fixed point grain-invariant.
    thr_ewma_alpha: float = 0.5

    max_batch_size: int = field(init=False)
    _prev_throughput: Optional[float] = field(default=None, init=False)
    _thr_ewma: Optional[float] = field(default=None, init=False)
    _prev_batch: int = field(default=0, init=False)
    _ceiling: Optional[float] = field(default=None, init=False)
    history: List[int] = field(default_factory=list, init=False)

    def __post_init__(self):
        self.max_batch_size = self.init_batch
        self._prev_batch = self.init_batch

    def update(self, m: LocalMetrics) -> int:
        """One Algorithm-1 iteration; returns the new max batch size."""
        slo = m.itl_slo if m.itl_slo > 0 else self.itl_slo
        # TBP judges the last growth step (see reproduction note above):
        # an absolute throughput regression after growing means the batch
        # size crossed the Fig. 3 inflection. LBP alone paces the EWMA
        # growth — using the TBP ratio as a growth divisor would throttle
        # proportionally to the step size, not to SLO proximity.
        grew = self.max_batch_size > self._prev_batch
        prev_thr = self._prev_throughput if grew else None
        a = self.thr_ewma_alpha
        thr = m.throughput if self._thr_ewma is None else \
            a * m.throughput + (1.0 - a) * self._thr_ewma
        self._thr_ewma = thr
        bp = local_backpressure(m.observed_itl, slo, prev_thr, thr)
        lbp = m.observed_itl / slo
        bs = float(self.max_batch_size)
        self._prev_batch = self.max_batch_size
        if bp > 1.0:
            self._ceiling = bs
            if bp < self.mild_violation:
                # proportional step back, floored at mild_decrease: a
                # barely-over-1 (smoothed) TBP excursion costs ~nothing,
                # so sampling noise cannot ratchet the ceiling down —
                # the EWMA bounds the excursion, this bounds its damage
                bs = bs * max(1.0 / bp, self.mild_decrease)
            else:
                bs = bs / 2.0
        else:
            if lbp <= 0.0:
                factor = self.max_growth
            else:
                factor = self.alpha * (1.0 / lbp) + (1.0 - self.alpha)
                factor = min(factor, self.max_growth)
            target = factor * bs
            if self._ceiling is not None:
                target = min(target, self.ceiling_shrink * self._ceiling)
                self._ceiling *= self.ceiling_relax
            if target > bs:
                target = max(target, bs + 1)   # don't stall on rounding
            bs = max(target, bs)   # a growth decision never shrinks
        self.max_batch_size = int(max(self.min_batch,
                                      min(self.max_batch, round(bs))))
        self._prev_throughput = thr
        self.history.append(self.max_batch_size)
        return self.max_batch_size

    def converged(self, window: int = 6, tol: float = 0.1) -> bool:
        """Batch size stable within +-tol over the last ``window`` updates."""
        if len(self.history) < window:
            return False
        tail = self.history[-window:]
        lo, hi = min(tail), max(tail)
        return hi - lo <= max(1, tol * hi)
