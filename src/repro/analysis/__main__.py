"""CLI: ``python -m repro.analysis src/ [--json] [--rules MIR,DET201]``.

Exit status 0 when no finding survives suppressions, 1 otherwise (the
``scripts/ci_fast.py`` zero-findings gate), 2 on usage errors.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.analysis import run_analysis


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Mirror-sync, determinism, and hygiene auditor "
                    "(see repro.analysis for the rule catalogue).")
    parser.add_argument("paths", nargs="+",
                        help="files or directories to analyze")
    parser.add_argument("--json", action="store_true", dest="as_json",
                        help="emit findings as a JSON array")
    parser.add_argument("--rules", default=None,
                        help="comma-separated rule ids or prefixes "
                             "(e.g. MIR,DET203) — default: all rules")
    args = parser.parse_args(argv)

    rules = [r.strip() for r in args.rules.split(",") if r.strip()] \
        if args.rules else None
    findings = run_analysis(args.paths, rules=rules)

    if args.as_json:
        print(json.dumps([f.to_dict() for f in findings], indent=2))
    else:
        for f in findings:
            print(f)
        print(f"repro.analysis: {len(findings)} finding(s)",
              file=sys.stderr)
    return 1 if findings else 0


if __name__ == "__main__":
    raise SystemExit(main())
