"""AST rule implementations for the invariant auditor.

See :mod:`repro.analysis` for the rule catalogue and suppression syntax.
The mirror registries are imported from the modules that declare them
(``repro.sim.ledger`` / ``repro.sim.cluster``) so the auditor can never
drift from the data structures it audits.
"""
from __future__ import annotations

import ast
import os
import re
from typing import Iterable, Iterator, List, Optional, Sequence, Tuple

from repro.analysis.findings import Finding, Suppressions
from repro.serving.global_queue import QUEUE_KEY_COLUMNS
from repro.sim.cluster import PLANE_CONTAINER_MIRRORS, PLANE_MIRRORS
from repro.sim.ledger import LEDGER_MIRRORS

# MIR103: the columnar queue's payload list — a subscript write to it
# must refresh every key column in the same function
_QUEUE_PAYLOAD = "req_objs"

# MIR104: terminal lifecycle writes (`req.state = RequestState.<T>` for a
# terminal T) must pair with a `state` column write mentioning the SAME
# terminal code name in the same function — MIR101 alone would accept a
# FINISHED column write as cover for a REJECTED object write.
_TERMINAL_NAMES = ("FINISHED", "REJECTED", "SHED", "EXPIRED")

# DET201: construction of *seeded* generators is the sanctioned idiom
_SEEDED_NP = frozenset({"default_rng", "Generator", "SeedSequence",
                        "RandomState", "PCG64", "Philox"})
_SEEDED_STDLIB = frozenset({"Random", "SystemRandom"})
# DET202: wall-clock reads (path-exempt under benchmarks/ and scripts/)
_WALL_CLOCK_TIME = frozenset({"time", "monotonic", "perf_counter",
                              "process_time"})
_CLOCK_EXEMPT_DIRS = frozenset({"benchmarks", "scripts"})
# DET204: identifier fragments that mark a total-order tiebreaker
_TIEBREAK_FRAGMENTS = ("seq", "id", "epoch", "kind")
# DET205: scheduled-event attributes vs current-time names
_EVENT_TIME_ATTRS = frozenset({"ready_time", "prefill_done_t"})
_CURRENT_TIME_NAMES = frozenset({"t", "now", "t_next", "t_arr"})
_CMP_OPS = (ast.Lt, ast.LtE, ast.Gt, ast.GtE, ast.Eq)

_MIRROR_SCOPES = ("repro/sim", "repro/serving")
_INIT_FUNCS = frozenset({"__init__", "__post_init__"})


def _norm(path: str) -> str:
    return path.replace(os.sep, "/")


def _mirror_rules_apply(path: str) -> bool:
    """MIR rules audit the simulator/serving planes (where the mirrored
    structures live); files elsewhere in the ``repro`` package are out of
    scope. Paths outside the package (fixtures, tmp files) get the full
    rule set so the auditor itself is testable."""
    norm = _norm(path)
    if "repro/" not in norm:
        return True
    return any(scope in norm for scope in _MIRROR_SCOPES)


def _wall_clock_exempt(path: str) -> bool:
    parts = _norm(path).split("/")
    return any(part in _CLOCK_EXEMPT_DIRS for part in parts)


def _attr_chain(node: ast.AST) -> Tuple[str, ...]:
    """``np.random.rand`` -> ('np', 'random', 'rand'); () when the chain
    roots in something other than a plain name."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return tuple(reversed(parts))
    return ()


def _functions(tree: ast.Module) -> Iterator[ast.AST]:
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


def _own_nodes(fn: ast.AST) -> Iterator[ast.AST]:
    """Every node in ``fn``'s body excluding nested function bodies
    (each nested function gets its own mirror-pairing scope)."""
    stack: List[ast.AST] = list(fn.body)
    while stack:
        node = stack.pop()
        yield node
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.Lambda)):
                continue
            stack.append(child)


def _flat_targets(node: ast.AST) -> Iterator[ast.AST]:
    """Assignment targets of ``node``, tuple/list targets flattened."""
    if isinstance(node, ast.Assign):
        targets: Iterable[ast.AST] = node.targets
    elif isinstance(node, ast.AugAssign):
        targets = (node.target,)
    elif isinstance(node, ast.AnnAssign) and node.value is not None:
        targets = (node.target,)
    else:
        return
    stack = list(targets)
    while stack:
        tgt = stack.pop()
        if isinstance(tgt, (ast.Tuple, ast.List)):
            stack.extend(tgt.elts)
        else:
            yield tgt


def _mentions(node: ast.AST, name: str) -> bool:
    for n in ast.walk(node):
        if isinstance(n, ast.Name) and n.id == name:
            return True
        if isinstance(n, ast.Attribute) and n.attr == name:
            return True
    return False


class _Collector:
    def __init__(self, path: str, supp: Suppressions,
                 rules: Optional[Sequence[str]]):
        self.path = path
        self.supp = supp
        self.rules = tuple(rules) if rules is not None else None
        self.findings: List[Finding] = []

    def emit(self, rule: str, line: int, message: str,
             fn_line: Optional[int] = None) -> None:
        if self.rules is not None and not any(
                rule == r or rule.startswith(r) for r in self.rules):
            return
        if self.supp.suppressed(rule, line) \
                or self.supp.suppressed(rule, fn_line):
            return
        self.findings.append(Finding(rule, self.path, line, message))


# --------------------------------------------------------- mirror rules
def _check_mirrors(tree: ast.Module, out: _Collector) -> None:
    """MIR101/MIR102: every object write to a mirrored attribute must be
    paired, in the same function, with the corresponding column write or
    a sync call (``_sync_plane`` / ``plane.alloc`` / ``plane.free``).
    MIR103: every queue payload write (``req_objs[i] = req``) must be
    paired, in the same function, with writes to every key column in
    :data:`repro.serving.global_queue.QUEUE_KEY_COLUMNS` (``None``
    assignments clear a freed cell and are exempt — the key cells behind
    the cursor are dead).
    MIR104: every *terminal* state write must pair with a ``state``
    column write naming the same terminal code (see
    :data:`_TERMINAL_NAMES`)."""
    for fn in _functions(tree):
        if fn.name in _INIT_FUNCS:
            continue
        obj_writes: List[Tuple[str, str, str, int]] = []
        payload_writes: List[int] = []
        term_writes: List[Tuple[str, int]] = []
        term_cols: set = set()
        mirror_cols = set()
        plane_synced = False

        def container_write(attr: str, lineno: int) -> None:
            obj_writes.append((attr, PLANE_CONTAINER_MIRRORS[attr],
                               "MIR102", lineno))

        for node in _own_nodes(fn):
            for tgt in _flat_targets(node):
                if isinstance(tgt, ast.Attribute):
                    a = tgt.attr
                    if a in LEDGER_MIRRORS:
                        # `state` is also an instance/engine attribute;
                        # only RequestState writes are the Request mirror
                        if a == "state" and not _mentions(node,
                                                          "RequestState"):
                            continue
                        obj_writes.append((a, LEDGER_MIRRORS[a], "MIR101",
                                           tgt.lineno))
                        if a == "state":
                            for term in _TERMINAL_NAMES:
                                if _mentions(node, term):
                                    term_writes.append((term, tgt.lineno))
                    elif a in PLANE_MIRRORS:
                        obj_writes.append((a, PLANE_MIRRORS[a], "MIR102",
                                           tgt.lineno))
                elif isinstance(tgt, ast.Subscript) \
                        and isinstance(tgt.value, ast.Attribute):
                    base = tgt.value.attr
                    if base in PLANE_CONTAINER_MIRRORS:
                        container_write(base, tgt.lineno)
                    else:
                        mirror_cols.add(base)
                        if base == "state":
                            for term in _TERMINAL_NAMES:
                                if _mentions(node, term):
                                    term_cols.add(term)
                        if base == _QUEUE_PAYLOAD \
                                and not (isinstance(node, ast.Assign)
                                         and isinstance(node.value,
                                                        ast.Constant)
                                         and node.value.value is None):
                            payload_writes.append(tgt.lineno)
            if isinstance(node, ast.Delete):
                for tgt in node.targets:
                    if isinstance(tgt, ast.Subscript) \
                            and isinstance(tgt.value, ast.Attribute) \
                            and tgt.value.attr in PLANE_CONTAINER_MIRRORS:
                        container_write(tgt.value.attr, tgt.lineno)
            elif isinstance(node, ast.Call) \
                    and isinstance(node.func, ast.Attribute):
                f = node.func
                if f.attr == "_sync_plane":
                    plane_synced = True
                elif f.attr in ("alloc", "free"):
                    recv = f.value
                    if (isinstance(recv, ast.Attribute)
                            and recv.attr == "plane") \
                            or (isinstance(recv, ast.Name)
                                and recv.id in ("plane", "pl")):
                        plane_synced = True
                elif f.attr == "clear" \
                        and isinstance(f.value, ast.Attribute) \
                        and f.value.attr in PLANE_CONTAINER_MIRRORS:
                    container_write(f.value.attr, node.lineno)

        missing = [c for c in QUEUE_KEY_COLUMNS if c not in mirror_cols]
        if missing:
            for lineno in payload_writes:
                out.emit("MIR103", lineno,
                         "queue payload write without the paired key-"
                         f"column write(s) {', '.join(missing)} in "
                         f"`{fn.name}` (suppress with "
                         "`# mirror-sync: ok(<reason>)` if the columns "
                         "are settled elsewhere)", fn_line=fn.lineno)

        for term, lineno in term_writes:
            if term not in term_cols:
                out.emit("MIR104", lineno,
                         f"terminal state write `RequestState.{term}` "
                         "without a `state` column write naming "
                         f"`{term}` in `{fn.name}` — route terminal "
                         "transitions through the RequestLedger "
                         "`mark_*` helpers (suppress with "
                         "`# mirror-sync: ok(<reason>)` if the column "
                         "is settled elsewhere)", fn_line=fn.lineno)

        for attr, col, rule, lineno in obj_writes:
            if col in mirror_cols:
                continue
            if rule == "MIR102" and plane_synced:
                continue
            kind = "ledger column" if rule == "MIR101" else "plane column"
            out.emit(rule, lineno,
                     f"write to mirrored attribute `{attr}` without the "
                     f"paired {kind} `{col}` write"
                     + ("" if rule == "MIR101"
                        else " or a _sync_plane()/plane.alloc/free call")
                     + f" in `{fn.name}` (suppress with "
                     "`# mirror-sync: ok(<reason>)` if the mirror is "
                     "settled by the caller)", fn_line=fn.lineno)


# ---------------------------------------------------- determinism rules
def _check_rng(tree: ast.Module, out: _Collector) -> None:
    """DET201: unseeded global RNG calls."""
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        chain = _attr_chain(node.func)
        if len(chain) == 2 and chain[0] == "random" \
                and chain[1] not in _SEEDED_STDLIB:
            out.emit("DET201", node.lineno,
                     f"unseeded global RNG `random.{chain[1]}()` — use a "
                     "seeded `random.Random(seed)` (or numpy "
                     "`default_rng`) instead")
        elif len(chain) == 3 and chain[0] in ("np", "numpy") \
                and chain[1] == "random" and chain[2] not in _SEEDED_NP:
            out.emit("DET201", node.lineno,
                     f"unseeded global RNG `{chain[0]}.random."
                     f"{chain[2]}()` — draw from a "
                     "`np.random.default_rng(seed)` Generator instead")


def _check_wall_clock(tree: ast.Module, out: _Collector) -> None:
    """DET202: wall-clock reads outside benchmarks//scripts/."""
    if _wall_clock_exempt(out.path):
        return
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        chain = _attr_chain(node.func)
        if not chain:
            continue
        bad = (len(chain) == 2 and chain[0] == "time"
               and chain[1] in _WALL_CLOCK_TIME) \
            or (chain[-1] in ("now", "today", "utcnow")
                and any(p in ("datetime", "date") for p in chain[:-1]))
        if bad:
            out.emit("DET202", node.lineno,
                     f"wall-clock read `{'.'.join(chain)}()` in simulation"
                     "/control code — thread sim time through instead "
                     "(wall clocks are only for benchmarks/ and scripts/)")


def _is_set_expr(node: ast.AST) -> bool:
    if isinstance(node, ast.Set) or isinstance(node, ast.SetComp):
        return True
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
            and node.func.id in ("set", "frozenset"):
        return True
    if isinstance(node, ast.BinOp) \
            and isinstance(node.op, (ast.BitOr, ast.BitAnd, ast.BitXor,
                                     ast.Sub)):
        return _is_set_expr(node.left) or _is_set_expr(node.right)
    return False


def _check_set_iteration(tree: ast.Module, out: _Collector) -> None:
    """DET203: iterating a set expression — address-dependent order."""
    iters: List[Tuple[ast.AST, int]] = []
    for node in ast.walk(tree):
        if isinstance(node, ast.For):
            iters.append((node.iter, node.iter.lineno))
        elif isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                               ast.GeneratorExp)):
            for gen in node.generators:
                iters.append((gen.iter, gen.iter.lineno))
    for expr, lineno in iters:
        if _is_set_expr(expr):
            out.emit("DET203", lineno,
                     "iteration over a set expression feeds an "
                     "address-dependent order into the run — wrap it in "
                     "sorted(...) to fix the order")


def _tuple_has_tiebreaker(key: ast.Tuple) -> bool:
    for elt in key.elts[1:]:
        if isinstance(elt, ast.Call) and isinstance(elt.func, ast.Name) \
                and elt.func.id == "next":
            return True
        name = None
        if isinstance(elt, ast.Name):
            name = elt.id
        elif isinstance(elt, ast.Attribute):
            name = elt.attr
        if name is not None and any(f in name.lower()
                                    for f in _TIEBREAK_FRAGMENTS):
            return True
    return False


def _check_heap_keys(tree: ast.Module, out: _Collector) -> None:
    """DET204: heappush keys must be total-order tuples with an explicit
    tiebreaker after the time (`(deadline, arrival, seq)` idiom) — raw
    objects in a heap compare by address or raise on ties."""
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        chain = _attr_chain(node.func)
        if not chain or chain[-1] != "heappush" or len(node.args) < 2:
            continue
        key = node.args[1]
        if not isinstance(key, ast.Tuple):
            out.emit("DET204", node.lineno,
                     "heappush key is not an inline tuple — push "
                     "`(time, ..., seq)` total-order tuples so ties "
                     "never compare payload objects")
        elif len(key.elts) < 2 or not _tuple_has_tiebreaker(key):
            out.emit("DET204", node.lineno,
                     "heappush tuple has no total-order tiebreaker "
                     "(a seq/id/epoch field or next(counter)) after "
                     "the primary time key")


def _check_event_time_compare(tree: ast.Module, out: _Collector) -> None:
    """DET205: raw comparisons between a scheduled event time and the
    current time lose events to accumulated float drift (the PR 3
    lost-READY bug) — compare against `t + eps` or clamp like
    `activate_if_ready(max(t, ready_time))`."""
    for node in ast.walk(tree):
        if not isinstance(node, ast.Compare):
            continue
        operands = [node.left] + list(node.comparators)
        for i, op in enumerate(node.ops):
            if not isinstance(op, _CMP_OPS):
                continue
            left, right = operands[i], operands[i + 1]
            for a, b in ((left, right), (right, left)):
                if isinstance(a, ast.Attribute) \
                        and a.attr in _EVENT_TIME_ATTRS \
                        and isinstance(b, ast.Name) \
                        and b.id in _CURRENT_TIME_NAMES:
                    out.emit("DET205", node.lineno,
                             f"raw comparison of scheduled `{a.attr}` "
                             f"against `{b.id}` — accumulated float "
                             "drift loses events at the boundary; "
                             "compare with an epsilon term or clamp "
                             "(`max(t, ready_time)`)")
                    break


# -------------------------------------------------------- hygiene rules
def _check_unused_imports(tree: ast.Module, source: str,
                          out: _Collector) -> None:
    """LINT301: module-level imports never referenced again."""
    if os.path.basename(out.path) == "__init__.py":
        return                       # re-export surface by convention
    binds: List[Tuple[str, int]] = []
    import_extents: List[Tuple[int, int]] = []
    for node in tree.body:
        if isinstance(node, ast.Import):
            import_extents.append((node.lineno, node.end_lineno))
            for alias in node.names:
                binds.append((alias.asname or alias.name.split(".")[0],
                              node.lineno))
        elif isinstance(node, ast.ImportFrom):
            if node.module == "__future__":
                continue
            import_extents.append((node.lineno, node.end_lineno))
            for alias in node.names:
                if alias.name == "*":
                    continue
                binds.append((alias.asname or alias.name, node.lineno))
    if not binds:
        return
    lines = source.splitlines()
    skip = set()
    for lo, hi in import_extents:
        skip.update(range(lo, (hi or lo) + 1))
    body = "\n".join(ln for i, ln in enumerate(lines, start=1)
                     if i not in skip)
    for name, lineno in binds:
        # word-boundary text search (not just Name nodes) so imports
        # used only inside quoted annotations don't false-positive
        if not re.search(rf"(?<![\w.]){re.escape(name)}\b", body):
            out.emit("LINT301", lineno,
                     f"`{name}` is imported but never used")


def _check_mutable_defaults(tree: ast.Module, out: _Collector) -> None:
    """LINT302: mutable default arguments are shared across calls."""
    for fn in _functions(tree):
        defaults = list(fn.args.defaults) + [d for d in fn.args.kw_defaults
                                             if d is not None]
        for d in defaults:
            mutable = isinstance(d, (ast.List, ast.Dict, ast.Set)) \
                or (isinstance(d, ast.Call)
                    and isinstance(d.func, ast.Name)
                    and d.func.id in ("list", "dict", "set"))
            if mutable:
                out.emit("LINT302", d.lineno,
                         f"mutable default argument in `{fn.name}` — "
                         "default to None and build inside the function")


def analyze_code(code: str, *, path: str = "<string>",
                 rules: Optional[Sequence[str]] = None) -> List[Finding]:
    """Run every rule over one module's source; returns findings sorted
    by line. ``rules`` narrows to the given rule ids (prefix match, so
    ``["MIR"]`` selects both mirror rules)."""
    tree = ast.parse(code, filename=path)
    out = _Collector(path, Suppressions(code), rules)
    if _mirror_rules_apply(path):
        _check_mirrors(tree, out)
    _check_rng(tree, out)
    _check_wall_clock(tree, out)
    _check_set_iteration(tree, out)
    _check_heap_keys(tree, out)
    _check_event_time_compare(tree, out)
    _check_unused_imports(tree, code, out)
    _check_mutable_defaults(tree, out)
    out.findings.sort(key=lambda f: (f.line, f.rule))
    return out.findings
