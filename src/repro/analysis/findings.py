"""Finding model + suppression-comment index for the static auditor."""
from __future__ import annotations

import re
from dataclasses import asdict, dataclass
from typing import Dict, Optional, Set

# `# mirror-sync: ok(<reason>)` — suppress MIR rules on a line / function
_MIRROR_OK = re.compile(r"#\s*mirror-sync:\s*ok\(([^)]*)\)")
# `# mirror-sync: module ok(<reason>)` — exempt the whole module from MIR
_MIRROR_MODULE_OK = re.compile(r"#\s*mirror-sync:\s*module\s+ok\(([^)]*)\)")
# `# repro-lint: ok(RULE_ID, <reason>)` — suppress one rule on a line
_LINT_OK = re.compile(r"#\s*repro-lint:\s*ok\(\s*([A-Z]+\d+)\s*(?:,([^)]*))?\)")

_MIR_ALL = "MIR*"


@dataclass(frozen=True)
class Finding:
    """One rule violation: where, which rule, and what to do about it."""
    rule: str
    path: str
    line: int
    message: str

    def to_dict(self) -> Dict[str, object]:
        return asdict(self)

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"


class Suppressions:
    """Per-line suppression index parsed from the raw source text.

    ``suppressed(rule, line)`` answers for single-line suppressions; the
    analyzer additionally consults the ``def`` line of the enclosing
    function so a suppression there covers the whole body. A suppression
    on a comment-only line also covers the next line, so long statements
    can carry one without blowing the line width.
    """

    def __init__(self, source: str):
        self.module_mirror_exempt = False
        self._by_line: Dict[int, Set[str]] = {}

        def add(lineno: int, rule: str, standalone: bool) -> None:
            self._by_line.setdefault(lineno, set()).add(rule)
            if standalone:
                self._by_line.setdefault(lineno + 1, set()).add(rule)

        for lineno, text in enumerate(source.splitlines(), start=1):
            if "#" not in text:
                continue
            standalone = text.lstrip().startswith("#")
            if _MIRROR_MODULE_OK.search(text):
                self.module_mirror_exempt = True
                continue
            if _MIRROR_OK.search(text):
                add(lineno, _MIR_ALL, standalone)
            m = _LINT_OK.search(text)
            if m:
                add(lineno, m.group(1), standalone)

    def suppressed(self, rule: str, line: Optional[int]) -> bool:
        if rule.startswith("MIR") and self.module_mirror_exempt:
            return True
        if line is None:
            return False
        rules = self._by_line.get(line)
        if not rules:
            return False
        if rule in rules:
            return True
        return rule.startswith("MIR") and _MIR_ALL in rules
