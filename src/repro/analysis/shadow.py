"""Runtime shadow verification of the columnar mirrors.

The static mirror auditor (:mod:`repro.analysis.checks`) proves every
mutation *site* pairs its object write with the column write — but it
cannot prove the paired writes store the same value, fire under the same
conditions, or that no site was exempted wrongly. The shadow verifier
closes that gap at runtime: the event engines
(``simulate_events``/``simulate_fleet`` with ``shadow_verify=True`` or
env ``CHIRON_SHADOW_VERIFY=1``) rebuild the ledger/plane columns from
the object view at control ticks and completion sweeps and assert
**exact** agreement — the mirrors are written at the same sites with the
same arithmetic, so any tolerance would only hide bugs.

Cost model: the plane check is O(instances) and runs at every control
tick and completion sweep; the ledger check is O(materialized requests)
and is throttled to every ``ledger_interval`` sim-seconds (pass ``0.0``
to check at every control tick — the deliberate-desync mutation test
needs that, since a corrupted in-flight cell is re-overwritten with the
correct value when the request finishes). A full ledger verification
always runs once more at the end of the run.
"""
from __future__ import annotations

import math
from typing import List, Optional

from repro.sim.ledger import STATE_CODES


class ShadowVerifyError(AssertionError):
    """A columnar mirror disagreed with the object view it shadows."""


def _fail(what: str, detail: str) -> None:
    raise ShadowVerifyError(f"shadow-verify: {what}: {detail}")


class ShadowVerifier:
    """Rebuild-and-compare harness for the ledger and instance plane.

    ``plane_checks`` / ``ledger_checks`` / ``queue_checks`` count
    completed verifications so tests can assert the hooks actually ran.
    """

    def __init__(self, ledger_interval: float = 30.0):
        self.ledger_interval = ledger_interval
        self._next_ledger = 0.0
        self.plane_checks = 0
        self.ledger_checks = 0
        self.queue_checks = 0

    # ---------------------------------------------------- instance plane
    def verify_cluster(self, cluster) -> None:
        """Columns vs object scalars for every live slot. Only meaningful
        while the plane is armed (``plane_live``) — below the vectorized
        cut-over the columns are deliberately stale and never read."""
        if not cluster.event_mode or not cluster.plane_live:
            return
        pl = cluster.plane
        for inst in cluster.instances:
            s = inst.slot
            if s < 0:
                continue
            where = f"instance {inst.id} slot {s}"
            checks = (
                ("active", bool(pl.active[s]), inst.active),
                ("n_running", int(pl.n_running[s]), len(inst.running)),
                ("n_dec", int(pl.n_dec[s]), inst._n_dec),
                ("kv_prefill", float(pl.kv_prefill[s]), inst._kv_prefill),
                ("kv_dec_base", float(pl.kv_dec_base[s]),
                 inst._kv_dec_base),
                ("vclock", float(pl.vclock[s]), inst.vclock),
                ("last_advance", float(pl.last_advance[s]),
                 inst.last_advance),
                ("slow", float(pl.slow[s]), inst.slow_factor),
            )
            for col, got, want in checks:
                if got != want:
                    _fail(f"plane column `{col}` out of sync",
                          f"{where}: column={got!r} object={want!r}")
            # mirrored heads must match the earliest *valid* heap entries
            # (cleaning pops only invalid entries — unobservable)
            np_, nv = inst._clean_heads()
            if float(pl.next_prefill[s]) != np_ \
                    or float(pl.next_vfin[s]) != nv:
                _fail("plane event heads out of sync",
                      f"{where}: column=({float(pl.next_prefill[s])!r}, "
                      f"{float(pl.next_vfin[s])!r}) "
                      f"cleaned=({np_!r}, {nv!r})")
        self.plane_checks += 1

    # ------------------------------------------------------------ queue
    def verify_queue(self, queue) -> None:
        """Key columns vs payload ``Request`` objects for every live lane
        window of a columnar :class:`~repro.serving.global_queue.
        GlobalQueue` (``QUEUE_MIRRORS``), plus the maintained
        interactive/batch counters against a recount. No-ops on the
        object-queue reference flavour (nothing columnar to shadow)."""
        if not getattr(queue, "columnar", False):
            return
        from repro.serving.global_queue import QUEUE_MIRRORS
        mirrors = sorted(QUEUE_MIRRORS.items())
        for kind, model, lane in queue.audit_lanes():
            for i in range(lane.head, lane.tail):
                req = lane.req_objs[i]
                where = f"{kind} lane {model!r} index {i}"
                if req is None:
                    _fail("queue payload cell empty",
                          f"{where}: live window holds None")
                for attr, col in mirrors:
                    got = float(getattr(lane, col)[i])
                    want = float(getattr(req, attr))
                    if got != want:
                        _fail(f"queue column `{col}` out of sync",
                              f"{where}: column={got!r} "
                              f"request.{attr}={want!r}")
        n_i, n_b = queue.audit_counts()
        if n_i != queue._icount or n_b != queue._bcount:
            _fail("queue counters out of sync",
                  f"recount=({n_i}, {n_b}) "
                  f"counters=({queue._icount}, {queue._bcount})")
        self.queue_checks += 1

    # ----------------------------------------------------------- ledger
    def verify_ledger(self, ledger, requests: List) -> None:
        """Outcome columns vs ``Request`` attributes over every
        materialized request with a ledger row."""
        if ledger is None:
            return
        state = ledger.state
        tokens = ledger.tokens_generated
        retries = ledger.retries
        ftt = ledger.first_token_time
        fin = ledger.finish_time
        mitl = ledger.mean_itl
        for r in requests:
            row = r.row
            if row < 0:
                continue
            where = f"request {r.req_id} row {row}"
            if int(state[row]) != STATE_CODES[r.state]:
                _fail("ledger `state` out of sync",
                      f"{where}: column={int(state[row])} "
                      f"object={r.state!r}")
            if int(tokens[row]) != r.tokens_generated:
                _fail("ledger `tokens_generated` out of sync",
                      f"{where}: column={int(tokens[row])} "
                      f"object={r.tokens_generated}")
            if int(retries[row]) != r.retries:
                _fail("ledger `retries` out of sync",
                      f"{where}: column={int(retries[row])} "
                      f"object={r.retries}")
            self._check_optional(ftt, row, r.first_token_time,
                                 "first_token_time", where)
            self._check_optional(fin, row, r.finish_time,
                                 "finish_time", where)
            cell = float(mitl[row])
            if not r.itl_samples:
                if not math.isnan(cell):
                    _fail("ledger `mean_itl` out of sync",
                          f"{where}: column={cell!r} but no ITL samples")
            elif math.isnan(cell):
                _fail("ledger `mean_itl` out of sync",
                      f"{where}: column=NaN but {len(r.itl_samples)} "
                      "ITL sample(s)")
            elif len(r.itl_samples) == 1 and cell != r.itl_samples[0]:
                # the event core records exactly one lifetime-mean sample
                # at finish; a single-sample mean is bit-exact
                _fail("ledger `mean_itl` out of sync",
                      f"{where}: column={cell!r} "
                      f"object={r.itl_samples[0]!r}")
        self.ledger_checks += 1

    @staticmethod
    def _check_optional(col, row: int, value: Optional[float],
                        name: str, where: str) -> None:
        cell = float(col[row])
        if value is None:
            if not math.isnan(cell):
                _fail(f"ledger `{name}` out of sync",
                      f"{where}: column={cell!r} object=None")
        elif cell != value:
            _fail(f"ledger `{name}` out of sync",
                  f"{where}: column={cell!r} object={value!r}")

    def maybe_verify_ledger(self, ledger, requests: List,
                            t: float) -> None:
        """Throttled ledger check (see class docstring)."""
        if t < self._next_ledger:
            return
        self._next_ledger = t + self.ledger_interval
        self.verify_ledger(ledger, requests)


def resolve(shadow_verify) -> Optional[ShadowVerifier]:
    """Normalize the engines' ``shadow_verify`` argument: a verifier
    passes through, True builds one, None consults the
    ``CHIRON_SHADOW_VERIFY`` environment variable."""
    if isinstance(shadow_verify, ShadowVerifier):
        return shadow_verify
    if shadow_verify is None:
        import os
        shadow_verify = os.environ.get("CHIRON_SHADOW_VERIFY", "") \
            not in ("", "0", "false", "no")
    return ShadowVerifier() if shadow_verify else None
