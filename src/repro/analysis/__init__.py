"""Static invariant auditor + runtime shadow-verify plane.

The columnar hot path (PR 4) keeps two views of every request and
instance — object fields (``Request``, ``SimInstance``) and columnar
mirrors (``RequestLedger``, ``InstancePlane``) — synchronized *by hand*
at each mutation site, and the decision-equivalence guarantees hang on
that discipline plus strict determinism (seeded RNG, totally-ordered
event heaps, epsilon-tolerant event-time comparisons). This package
machine-checks those invariants instead of remembering them:

- **Mirror-sync auditor** (``MIR1xx``): machine-readable mirror
  registries declared next to the data structures
  (:data:`repro.sim.ledger.LEDGER_MIRRORS`,
  :data:`repro.sim.cluster.PLANE_MIRRORS` /
  :data:`~repro.sim.cluster.PLANE_CONTAINER_MIRRORS`,
  :data:`repro.serving.global_queue.QUEUE_MIRRORS`) drive an AST walk
  that flags any assignment to a mirrored attribute not paired — in the
  same function — with the corresponding ledger/plane column write or a
  ``_sync_plane()`` / ``plane.alloc`` / ``plane.free`` call, and any
  columnar-queue payload write not paired with its key-column writes.
- **Determinism & heap-discipline lints** (``DET2xx``): unseeded global
  RNG, wall-clock reads outside ``benchmarks/``/``scripts/``, iteration
  over set expressions (address-dependent order) feeding decisions,
  ``heapq.heappush`` keys that are not total-order tuples, and raw
  comparisons of scheduled event times without an epsilon (the PR 3
  lost-READY bug class).
- **Hygiene lints** (``LINT3xx``): unused imports and mutable default
  arguments — the in-container stand-ins for the ruff rules pinned in
  ``requirements-dev.txt`` (the gate runs both when ruff is installed).
- **Shadow-verify plane** (:mod:`repro.analysis.shadow`): at runtime,
  ``simulate_events(..., shadow_verify=True)`` (env
  ``CHIRON_SHADOW_VERIFY=1``) rebuilds the ledger/plane/queue columns
  from the objects at control ticks and completion sweeps and asserts
  exact agreement — any sync bug the static pass can't see fails loudly.

Rule catalogue
==============

========  ============================================================
rule id   flags
========  ============================================================
MIR101    ``Request`` mirrored-attribute write without the paired
          ``ledger.<col>[row]`` write in the same function
MIR102    ``SimInstance`` mirrored-scalar (or ``running`` container)
          write without a paired plane column write / ``_sync_plane()``
          / ``plane.alloc``/``free`` in the same function
MIR103    columnar-queue payload write (``req_objs[i] = req``) without
          paired writes to every key column (``seq``, ``arrival``,
          ``deadline``, ``row``) in the same function (``None``
          cell-clears exempt)
MIR104    terminal lifecycle write (``req.state = RequestState.<T>``
          for T in FINISHED/REJECTED/SHED/EXPIRED) without a ``state``
          column write naming the *same* terminal code in the same
          function (MIR101 alone cannot tell the codes apart)
DET201    unseeded global RNG: ``random.<fn>()`` or ``np.random.<fn>()``
          not going through ``default_rng``/``Generator``/``SeedSequence``
DET202    wall-clock read (``time.time``/``monotonic``/``perf_counter``,
          ``datetime.now``) outside ``benchmarks/``/``scripts/``
DET203    ``for``/comprehension over a set expression (set literal,
          ``set(...)``, unions/intersections of sets) without ``sorted``
DET204    ``heapq.heappush`` key that is not a tuple of >= 2 elements
          with a total-order tiebreaker (a ``seq``/``id``/``epoch``
          field or ``next(<counter>)``) after the time
DET205    raw ``<``/``<=``/``>``/``>=``/``==`` between a scheduled
          event-time attribute (``ready_time``, ``prefill_done_t``) and
          a current-time variable without an epsilon term
LINT301   unused module-level import
LINT302   mutable default argument (list/dict/set literal or call)
========  ============================================================

Suppressions
============

- ``# mirror-sync: ok(<reason>)`` on the offending line suppresses the
  MIR rules there; on a ``def`` line it exempts the whole function (the
  gated ``plane_live`` fast paths where callers settle + sync).
- ``# mirror-sync: module ok(<reason>)`` anywhere in a file exempts the
  whole module from the MIR rules (the real-engine modules, which have
  no ledger/plane to mirror into).
- ``# repro-lint: ok(RULE_ID, <reason>)`` suppresses any one rule on
  that line (or function, when on the ``def`` line).

Run ``python -m repro.analysis src/`` (``--json`` for findings-as-JSON);
exit status 1 when any finding survives. ``scripts/ci_fast.py`` runs it
as a blocking zero-findings gate.
"""
from __future__ import annotations

import os
from typing import List, Optional, Sequence

from repro.analysis.findings import Finding, Suppressions
from repro.analysis.checks import analyze_code
from repro.analysis.shadow import ShadowVerifier, ShadowVerifyError

__all__ = ["Finding", "Suppressions", "analyze_code", "analyze_file",
           "run_analysis", "iter_py_files", "ShadowVerifier",
           "ShadowVerifyError"]


def analyze_file(path: str, *, rules: Optional[Sequence[str]] = None,
                 ) -> List[Finding]:
    """Analyze one Python file (all rules unless ``rules`` narrows)."""
    with open(path, encoding="utf-8") as f:
        code = f.read()
    return analyze_code(code, path=path, rules=rules)


def iter_py_files(paths: Sequence[str]) -> List[str]:
    """Expand files/directories into a sorted list of ``.py`` files."""
    out: List[str] = []
    for p in paths:
        if os.path.isdir(p):
            for root, dirs, files in os.walk(p):
                dirs[:] = sorted(d for d in dirs
                                 if d not in ("__pycache__", ".git"))
                for name in sorted(files):
                    if name.endswith(".py"):
                        out.append(os.path.join(root, name))
        elif p.endswith(".py"):
            out.append(p)
    return sorted(dict.fromkeys(out))


def run_analysis(paths: Sequence[str], *,
                 rules: Optional[Sequence[str]] = None) -> List[Finding]:
    """Analyze every ``.py`` file under ``paths``; findings sorted by
    (path, line, rule). The mirror rules only apply inside the
    simulator/serving planes (the structures they audit live there);
    every other rule applies tree-wide."""
    findings: List[Finding] = []
    for path in iter_py_files(paths):
        findings.extend(analyze_file(path, rules=rules))
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings
