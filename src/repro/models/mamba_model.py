"""Pure-SSM (Mamba2) decoder model: attention-free, O(1) decode state."""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.ssm import init_mamba_layer, mamba_decode, mamba_forward

Params = Dict[str, Any]


def init_params(cfg: ModelConfig, key, dtype=None) -> Params:
    dtype = dtype or jnp.dtype(cfg.dtype)
    ke, kl, kn = jax.random.split(key, 3)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    stacked = jax.vmap(lambda k: init_mamba_layer(cfg, k, dtype))(layer_keys)
    return {
        "emb": L.init_embeddings(cfg, ke, dtype),
        "layers": stacked,
        "final_norm": {"w": jnp.ones((cfg.d_model,), dtype)},
    }


def forward(cfg: ModelConfig, params: Params, tokens: jax.Array, *,
            remat: bool = False) -> Tuple[jax.Array, jax.Array]:
    x = L.embed(params["emb"], tokens)

    def body(x, lp):
        x, _, _ = mamba_forward(cfg, lp, x)
        return x, None

    step = jax.checkpoint(body) if remat else body
    x, _ = L.layer_scan(step, x, params["layers"])
    x = L.rms_norm(x, params["final_norm"]["w"])
    return L.unembed(params["emb"], x), jnp.zeros((), jnp.float32)


def init_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype) -> Dict[str, jax.Array]:
    del cache_len  # SSM state is O(1) in context length
    H, P, N = cfg.n_ssm_heads, cfg.ssm.head_dim, cfg.ssm.state_dim
    ch = cfg.d_inner + 2 * N
    return {
        "ssm": jnp.zeros((cfg.n_layers, batch, H, P, N), jnp.float32),
        "conv": jnp.zeros((cfg.n_layers, batch, cfg.ssm.conv_width - 1, ch), dtype),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


def prefill(cfg: ModelConfig, params: Params, tokens: jax.Array, *,
            dtype=None, **_) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    dtype = dtype or jnp.dtype(cfg.dtype)
    B, S = tokens.shape
    x = L.embed(params["emb"], tokens)

    def body(x, lp):
        x, h, conv = mamba_forward(cfg, lp, x)
        return x, (h, conv.astype(dtype))

    x, (hs, convs) = L.layer_scan(body, x, params["layers"])
    x = L.rms_norm(x, params["final_norm"]["w"])
    logits = L.unembed(params["emb"], x[:, -1:])
    cache = {"ssm": hs, "conv": convs,
             "pos": jnp.full((B,), S, jnp.int32)}
    return logits[:, 0], cache


def decode_step(cfg: ModelConfig, params: Params, tokens: jax.Array,
                cache: Dict[str, jax.Array]) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    x = L.embed(params["emb"], tokens)

    def body(x, inp):
        lp, h, conv = inp
        x, h, conv = mamba_decode(cfg, lp, x, h, conv)
        return x, (h, conv)

    x, (hs, convs) = L.layer_scan(body, x, (params["layers"], cache["ssm"],
                                            cache["conv"]))
    x = L.rms_norm(x, params["final_norm"]["w"])
    logits = L.unembed(params["emb"], x)[:, 0]
    return logits, dict(cache, ssm=hs, conv=convs, pos=cache["pos"] + 1)
