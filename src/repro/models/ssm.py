"""Mamba2 (SSD — state-space duality) layers. [arXiv:2405.21060]

Implements the chunked SSD algorithm in pure jnp (this is also the oracle
the Pallas ``ssd_scan`` kernel is validated against), a recurrent one-token
decode step, and the full block (in_proj -> conv -> SSD -> gated norm ->
out_proj) used by the ``ssm`` and ``hybrid`` architectures.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import _dense_init, rms_norm

Params = Dict[str, Any]


# ------------------------------------------------------------- SSD core


def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
                C: jax.Array, chunk: int,
                h0: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
    """Chunked state-space-duality scan (Mamba2 Listing 1, jnp).

    x  (b, s, h, p)   per-head inputs
    dt (b, s, h)      softplus'd step sizes
    A  (h,)           negative decay rates
    B  (b, s, n)      input projections (single group, broadcast over heads)
    C  (b, s, n)      output projections
    h0 optional initial state (b, h, p, n)

    Returns (y (b,s,h,p), final_state (b,h,p,n)).
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    if s % chunk:
        # pad to a chunk multiple: dt=0 makes padded steps identity
        # (decay exp(0)=1, zero input), so the final state is unaffected.
        pad = chunk - s % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
        y, h_final = ssd_chunked(x, dt, A, B, C, chunk, h0=h0)
        return y[:, :s], h_final
    nc = s // chunk

    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h)
    Bc = B.reshape(b, nc, chunk, n)
    Cc = C.reshape(b, nc, chunk, n)

    dA = dtc * A  # (b,nc,cs,h), negative
    dA_cum = jnp.cumsum(dA, axis=2)

    # --- intra-chunk (quadratic attention-like term)
    # L[i,j] = exp(dA_cum[i] - dA_cum[j]) for i >= j else 0
    li = dA_cum[:, :, :, None, :]      # (b,nc,cs,1,h)
    lj = dA_cum[:, :, None, :, :]      # (b,nc,1,cs,h)
    mask = (jnp.arange(chunk)[:, None] >= jnp.arange(chunk)[None, :])
    # mask the EXPONENT, not the result: for i<j the exponent is positive
    # and exp overflows to inf, which poisons gradients through where().
    arg = jnp.where(mask[None, None, :, :, None], li - lj, -jnp.inf)
    Lmat = jnp.exp(arg)
    scores = jnp.einsum("bzin,bzjn->bzij", Cc.astype(jnp.float32),
                        Bc.astype(jnp.float32))
    # (b,nc,i,j) x (b,nc,i,j,h) x dt_j -> weight per head
    w = scores[..., None] * Lmat * dtc[:, :, None, :, :]
    y_diag = jnp.einsum("bzijh,bzjhp->bzihp", w, xc.astype(jnp.float32))

    # --- per-chunk final states
    decay_to_end = jnp.exp(dA_cum[:, :, -1:, :] - dA_cum)   # (b,nc,cs,h)
    states = jnp.einsum("bzjn,bzjh,bzjhp->bzhpn",
                        Bc.astype(jnp.float32),
                        (decay_to_end * dtc).astype(jnp.float32),
                        xc.astype(jnp.float32))             # (b,nc,h,p,n)

    # --- inter-chunk recurrence: h_{z} entering chunk z
    chunk_decay = jnp.exp(dA_cum[:, :, -1, :])               # (b,nc,h)
    h_init = jnp.zeros((b, h, p, n), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def scan_fn(h_prev, inp):
        st, dec = inp  # (b,h,p,n), (b,h)
        h_new = dec[:, :, None, None] * h_prev + st
        return h_new, h_prev

    states_t = jnp.moveaxis(states, 1, 0)        # (nc,b,h,p,n)
    decay_t = jnp.moveaxis(chunk_decay, 1, 0)    # (nc,b,h)
    h_final, h_entering = jax.lax.scan(scan_fn, h_init, (states_t, decay_t))
    h_entering = jnp.moveaxis(h_entering, 0, 1)  # (b,nc,h,p,n)

    # --- inter-chunk output: decayed initial state of each chunk
    y_off = jnp.einsum("bzin,bzih,bzhpn->bzihp",
                       Cc.astype(jnp.float32),
                       jnp.exp(dA_cum),
                       h_entering)

    y = (y_diag + y_off).reshape(b, s, h, p).astype(x.dtype)
    return y, h_final


def ssd_decode_step(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
                    C: jax.Array, h: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """One recurrent step. x (b,h,p), dt (b,h), B/C (b,n), h (b,h,p,n)."""
    dA = jnp.exp(dt * A)                                     # (b,h)
    hf = h.astype(jnp.float32)
    upd = (dt[:, :, None] * x.astype(jnp.float32))[..., None] * \
        B.astype(jnp.float32)[:, None, None, :]              # (b,h,p,n)
    h_new = dA[:, :, None, None] * hf + upd
    y = jnp.einsum("bhpn,bn->bhp", h_new, C.astype(jnp.float32))
    return y.astype(x.dtype), h_new


# ------------------------------------------------------------- Mamba2 block


def init_mamba_layer(cfg: ModelConfig, key, dtype) -> Params:
    d = cfg.d_model
    di = cfg.d_inner
    N = cfg.ssm.state_dim
    H = cfg.n_ssm_heads
    conv_ch = di + 2 * N
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        # projects to [z (di), x (di), B (N), C (N), dt (H)]
        "w_in": _dense_init(k1, (d, 2 * di + 2 * N + H), dtype),
        "conv_w": _dense_init(k2, (cfg.ssm.conv_width, conv_ch), dtype,
                              scale=1.0 / math.sqrt(cfg.ssm.conv_width)),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm_w": jnp.ones((di,), dtype),
        "w_out": _dense_init(k3, (di, d), dtype),
        "rms_w": jnp.ones((d,), dtype),   # pre-norm
    }


def _split_proj(cfg: ModelConfig, proj: jax.Array):
    di, N, H = cfg.d_inner, cfg.ssm.state_dim, cfg.n_ssm_heads
    z = proj[..., :di]
    xBC = proj[..., di:di + di + 2 * N]
    dt = proj[..., di + di + 2 * N:]
    return z, xBC, dt


def _causal_conv(xBC: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv1d. xBC (b,s,ch), w (width,ch)."""
    width = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xBC.shape[1], :] * w[i] for i in range(width))
    return jax.nn.silu(out + b)


def mamba_forward(cfg: ModelConfig, p: Params, x: jax.Array,
                  h0: Optional[jax.Array] = None,
                  conv0: Optional[jax.Array] = None):
    """Full-sequence Mamba2 block. x (b,s,d) -> (y, final_ssm_state, conv_state)."""
    b, s, d = x.shape
    di, N, H = cfg.d_inner, cfg.ssm.state_dim, cfg.n_ssm_heads
    P = cfg.ssm.head_dim
    hid = rms_norm(x, p["rms_w"])
    proj = hid @ p["w_in"]
    z, xBC, dt_raw = _split_proj(cfg, proj)
    if conv0 is not None:
        xBC_ext = jnp.concatenate([conv0.astype(xBC.dtype), xBC], axis=1)
        conv_out = _causal_conv(xBC_ext, p["conv_w"], p["conv_b"])[:, conv0.shape[1]:]
    else:
        conv_out = _causal_conv(xBC, p["conv_w"], p["conv_b"])
    xs = conv_out[..., :di].reshape(b, s, H, P)
    B = conv_out[..., di:di + N]
    C = conv_out[..., di + N:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    # routed through kernels.ops: Pallas ssd_scan on TPU, the jnp oracle
    # (ssd_chunked below) elsewhere
    from repro.kernels import ops as _kops
    y, h_final = _kops.ssd_scan(xs, dt, A, B, C, h0,
                                chunk=cfg.ssm.chunk_size)
    y = y + p["D"][None, None, :, None].astype(y.dtype) * xs
    y = y.reshape(b, s, di)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"])
    out = y @ p["w_out"]
    conv_state = xBC[:, -(cfg.ssm.conv_width - 1):, :]
    return x + out, h_final, conv_state


def mamba_decode(cfg: ModelConfig, p: Params, x: jax.Array,
                 h: jax.Array, conv_state: jax.Array):
    """One-token step. x (b,1,d); h (b,H,P,N); conv_state (b,width-1,ch)."""
    b = x.shape[0]
    di, N, H = cfg.d_inner, cfg.ssm.state_dim, cfg.n_ssm_heads
    P = cfg.ssm.head_dim
    hid = rms_norm(x, p["rms_w"])
    proj = hid @ p["w_in"]
    z, xBC, dt_raw = _split_proj(cfg, proj)
    window = jnp.concatenate([conv_state.astype(xBC.dtype), xBC], axis=1)
    w = p["conv_w"]
    conv_out = jax.nn.silu(
        jnp.einsum("bwc,wc->bc", window, w) + p["conv_b"])[:, None, :]
    xs = conv_out[..., :di].reshape(b, H, P)
    B = conv_out[:, 0, di:di + N]
    C = conv_out[:, 0, di + N:]
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, h_new = ssd_decode_step(xs, dt, A, B, C, h)
    y = y + p["D"][None, :, None].astype(y.dtype) * xs
    y = y.reshape(b, 1, di)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"])
    out = y @ p["w_out"]
    new_conv = window[:, 1:, :]
    return x + out, h_new, new_conv
