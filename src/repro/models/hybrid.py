"""Zamba2-style hybrid: Mamba2 backbone + one shared attention block applied
every ``cfg.attn_every`` layers (each invocation keeps its own KV cache but
re-uses the same weights). [arXiv:2411.15242]"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.ssm import init_mamba_layer, mamba_decode, mamba_forward

Params = Dict[str, Any]


def _n_groups(cfg: ModelConfig) -> int:
    assert cfg.n_layers % cfg.attn_every == 0
    return cfg.n_layers // cfg.attn_every


def init_params(cfg: ModelConfig, key, dtype=None) -> Params:
    dtype = dtype or jnp.dtype(cfg.dtype)
    ke, kl, ks, kn = jax.random.split(key, 4)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    stacked = jax.vmap(lambda k: init_mamba_layer(cfg, k, dtype))(layer_keys)
    ka, kf, k1, k2 = jax.random.split(ks, 4)
    shared = {
        "attn": L.init_attention(cfg, ka, dtype),
        "ffn": L.init_ffn(cfg, kf, dtype),
        "norm1": L.init_norm(cfg, k1, dtype),
        "norm2": L.init_norm(cfg, k2, dtype),
    }
    return {
        "emb": L.init_embeddings(cfg, ke, dtype),
        "layers": stacked,
        "shared": shared,
        "final_norm": {"w": jnp.ones((cfg.d_model,), dtype)},
    }


def _group_slice(stacked: Params, g: int, size: int) -> Params:
    return jax.tree.map(lambda a: a[g * size:(g + 1) * size], stacked)


def _shared_block_forward(cfg: ModelConfig, sp: Params, x: jax.Array,
                          positions: jax.Array) -> jax.Array:
    h = L.apply_norm(cfg, sp["norm1"], x)
    x = x + L.attention_forward(cfg, sp["attn"], h, positions=positions)
    h = L.apply_norm(cfg, sp["norm2"], x)
    return x + L.ffn_forward(cfg, sp["ffn"], h)


def forward(cfg: ModelConfig, params: Params, tokens: jax.Array, *,
            remat: bool = False) -> Tuple[jax.Array, jax.Array]:
    B, S = tokens.shape
    x = L.embed(params["emb"], tokens)
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    G, A = _n_groups(cfg), cfg.attn_every

    def mamba_body(x, lp):
        x, _, _ = mamba_forward(cfg, lp, x)
        return x, None

    step = jax.checkpoint(mamba_body) if remat else mamba_body
    for g in range(G):
        x, _ = L.layer_scan(step, x, _group_slice(params["layers"], g, A))
        x = _shared_block_forward(cfg, params["shared"], x, positions)
    x = L.rms_norm(x, params["final_norm"]["w"])
    return L.unembed(params["emb"], x), jnp.zeros((), jnp.float32)


def init_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype) -> Dict[str, jax.Array]:
    G = _n_groups(cfg)
    H, P, N = cfg.n_ssm_heads, cfg.ssm.head_dim, cfg.ssm.state_dim
    ch = cfg.d_inner + 2 * N
    hd = cfg.resolved_head_dim
    return {
        "ssm": jnp.zeros((cfg.n_layers, batch, H, P, N), jnp.float32),
        "conv": jnp.zeros((cfg.n_layers, batch, cfg.ssm.conv_width - 1, ch), dtype),
        "k": jnp.zeros((G, batch, cache_len, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((G, batch, cache_len, cfg.n_kv_heads, hd), dtype),
        "pos": jnp.zeros((batch,), jnp.int32),
        "slot_pos": jnp.full((batch, cache_len), -1, jnp.int32),
    }


def prefill(cfg: ModelConfig, params: Params, tokens: jax.Array, *,
            cache_len: Optional[int] = None, dtype=None, **_):
    dtype = dtype or jnp.dtype(cfg.dtype)
    B, S = tokens.shape
    window = cfg.sliding_window or 0
    clen = cache_len or (min(S, window) if window else S)
    x = L.embed(params["emb"], tokens)
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    G, A = _n_groups(cfg), cfg.attn_every

    def mamba_body(x, lp):
        x, h, conv = mamba_forward(cfg, lp, x)
        return x, (h, conv.astype(dtype))

    hs, convs, ks, vs = [], [], [], []
    sp = params["shared"]
    for g in range(G):
        x, (h, conv) = L.layer_scan(mamba_body, x,
                                    _group_slice(params["layers"], g, A))
        hs.append(h); convs.append(conv)
        hnorm = L.apply_norm(cfg, sp["norm1"], x)
        o, k, v = L.attention_forward(cfg, sp["attn"], hnorm,
                                      positions=positions, return_kv=True)
        x = x + o
        hnorm = L.apply_norm(cfg, sp["norm2"], x)
        x = x + L.ffn_forward(cfg, sp["ffn"], hnorm)
        ks.append(k.astype(dtype)); vs.append(v.astype(dtype))

    k_all, v_all, spos = L.fit_cache(jnp.stack(ks), jnp.stack(vs), S, clen,
                                     window, B)
    cache = {
        "ssm": jnp.concatenate(hs, axis=0),
        "conv": jnp.concatenate(convs, axis=0),
        "k": k_all, "v": v_all,
        "pos": jnp.full((B,), S, jnp.int32),
        "slot_pos": spos,
    }
    x = L.rms_norm(x, params["final_norm"]["w"])
    logits = L.unembed(params["emb"], x[:, -1:])
    return logits[:, 0], cache


def decode_step(cfg: ModelConfig, params: Params, tokens: jax.Array,
                cache: Dict[str, jax.Array]):
    B = tokens.shape[0]
    x = L.embed(params["emb"], tokens)
    pos = cache["pos"]
    Sc = cache["k"].shape[2]
    slot = pos % Sc if cfg.sliding_window > 0 else pos
    slot_pos = cache["slot_pos"].at[jnp.arange(B), slot].set(pos)
    G, A = _n_groups(cfg), cfg.attn_every
    sp = params["shared"]

    def mamba_body(x, inp):
        lp, h, conv = inp
        x, h, conv = mamba_decode(cfg, lp, x, h, conv)
        return x, (h, conv)

    hs, convs, ks, vs = [], [], [], []
    for g in range(G):
        grp = (_group_slice(params["layers"], g, A),
               cache["ssm"][g * A:(g + 1) * A],
               cache["conv"][g * A:(g + 1) * A])
        x, (h, conv) = L.layer_scan(mamba_body, x, grp)
        hs.append(h); convs.append(conv)
        hnorm = L.apply_norm(cfg, sp["norm1"], x)
        o, kc, vc = L.attention_decode(cfg, sp["attn"], hnorm, cache["k"][g],
                                       cache["v"][g], pos, slot_pos)
        x = x + o
        hnorm = L.apply_norm(cfg, sp["norm2"], x)
        x = x + L.ffn_forward(cfg, sp["ffn"], hnorm)
        ks.append(kc); vs.append(vc)

    x = L.rms_norm(x, params["final_norm"]["w"])
    logits = L.unembed(params["emb"], x)[:, 0]
    new_cache = dict(cache,
                     ssm=jnp.concatenate(hs, axis=0),
                     conv=jnp.concatenate(convs, axis=0),
                     k=jnp.stack(ks), v=jnp.stack(vs),
                     pos=pos + 1, slot_pos=slot_pos)
    return logits, new_cache
