"""Whisper-style encoder-decoder transformer backbone. [arXiv:2212.04356]

Per the task carve-out, the mel-spectrogram + conv frontend is a stub: the
model consumes precomputed frame embeddings (B, enc_seq, d_model). Everything
downstream — bidirectional encoder, causal decoder with self + cross
attention, KV caches — is implemented for real.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L

Params = Dict[str, Any]


def _init_enc_layer(cfg: ModelConfig, key, dtype) -> Params:
    ka, kf, k1, k2 = jax.random.split(key, 4)
    return {"attn": L.init_attention(cfg, ka, dtype),
            "ffn": L.init_ffn(cfg, kf, dtype),
            "norm1": L.init_norm(cfg, k1, dtype),
            "norm2": L.init_norm(cfg, k2, dtype)}


def _init_dec_layer(cfg: ModelConfig, key, dtype) -> Params:
    ka, kc, kf, k1, k2, k3 = jax.random.split(key, 6)
    return {"self_attn": L.init_attention(cfg, ka, dtype),
            "cross_attn": L.init_attention(cfg, kc, dtype),
            "ffn": L.init_ffn(cfg, kf, dtype),
            "norm1": L.init_norm(cfg, k1, dtype),
            "norm2": L.init_norm(cfg, k2, dtype),
            "norm3": L.init_norm(cfg, k3, dtype)}


def init_params(cfg: ModelConfig, key, dtype=None) -> Params:
    dtype = dtype or jnp.dtype(cfg.dtype)
    ke, kenc, kdec, kp, kn = jax.random.split(key, 5)
    enc_keys = jax.random.split(kenc, cfg.n_enc_layers)
    dec_keys = jax.random.split(kdec, cfg.n_layers)
    return {
        "emb": L.init_embeddings(cfg, ke, dtype),
        "enc_pos": (jax.random.normal(kp, (cfg.enc_seq, cfg.d_model)) * 0.02).astype(dtype),
        "enc_layers": jax.vmap(lambda k: _init_enc_layer(cfg, k, dtype))(enc_keys),
        "dec_layers": jax.vmap(lambda k: _init_dec_layer(cfg, k, dtype))(dec_keys),
        "enc_norm": L.init_norm(cfg, kn, dtype),
        "final_norm": L.init_norm(cfg, kn, dtype),
    }


def encode(cfg: ModelConfig, params: Params, frames: jax.Array) -> jax.Array:
    """frames (B, T, d) stub embeddings -> encoder states (B, T, d)."""
    x = frames + params["enc_pos"][None, :frames.shape[1]].astype(frames.dtype)

    def body(x, lp):
        h = L.apply_norm(cfg, lp["norm1"], x)
        x = x + L.attention_forward(cfg, lp["attn"], h, causal=False,
                                    use_rope=False)
        h = L.apply_norm(cfg, lp["norm2"], x)
        return x + L.ffn_forward(cfg, lp["ffn"], h), None

    x, _ = L.layer_scan(body, x, params["enc_layers"])
    return L.apply_norm(cfg, params["enc_norm"], x)


def _dec_layer_full(cfg, lp, x, enc, positions):
    h = L.apply_norm(cfg, lp["norm1"], x)
    x = x + L.attention_forward(cfg, lp["self_attn"], h, positions=positions)
    h = L.apply_norm(cfg, lp["norm2"], x)
    x = x + L.attention_forward(cfg, lp["cross_attn"], h, kv_x=enc,
                                causal=False, use_rope=False)
    h = L.apply_norm(cfg, lp["norm3"], x)
    return x + L.ffn_forward(cfg, lp["ffn"], h)


def forward(cfg: ModelConfig, params: Params, tokens: jax.Array, *,
            frames: jax.Array, remat: bool = False) -> Tuple[jax.Array, jax.Array]:
    enc = encode(cfg, params, frames)
    B, S = tokens.shape
    x = L.embed(params["emb"], tokens)
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))

    def body(x, lp):
        return _dec_layer_full(cfg, lp, x, enc, positions), None

    step = jax.checkpoint(body) if remat else body
    x, _ = L.layer_scan(step, x, params["dec_layers"])
    x = L.apply_norm(cfg, params["final_norm"], x)
    return L.unembed(params["emb"], x), jnp.zeros((), jnp.float32)


def init_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype) -> Dict[str, jax.Array]:
    hd = cfg.resolved_head_dim
    c = {
        "k": jnp.zeros((cfg.n_layers, batch, cache_len, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((cfg.n_layers, batch, cache_len, cfg.n_kv_heads, hd), dtype),
        "cross_k": jnp.zeros((cfg.n_layers, batch, cfg.enc_seq, cfg.n_kv_heads, hd), dtype),
        "cross_v": jnp.zeros((cfg.n_layers, batch, cfg.enc_seq, cfg.n_kv_heads, hd), dtype),
        "pos": jnp.zeros((batch,), jnp.int32),
        "slot_pos": jnp.full((batch, cache_len), -1, jnp.int32),
    }
    return c


def prefill(cfg: ModelConfig, params: Params, tokens: jax.Array, *,
            frames: jax.Array, cache_len: Optional[int] = None,
            dtype=None, **_):
    dtype = dtype or jnp.dtype(cfg.dtype)
    enc = encode(cfg, params, frames)
    B, S = tokens.shape
    window = cfg.sliding_window or 0
    clen = cache_len or (min(S, window) if window else S)
    x = L.embed(params["emb"], tokens)
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    hd = cfg.resolved_head_dim

    def body(x, lp):
        h = L.apply_norm(cfg, lp["norm1"], x)
        o, k, v = L.attention_forward(cfg, lp["self_attn"], h,
                                      positions=positions, return_kv=True)
        x = x + o
        h = L.apply_norm(cfg, lp["norm2"], x)
        ck = (enc @ lp["cross_attn"]["wk"]).reshape(B, -1, cfg.n_kv_heads, hd)
        cv = (enc @ lp["cross_attn"]["wv"]).reshape(B, -1, cfg.n_kv_heads, hd)
        x = x + L.attention_forward(cfg, lp["cross_attn"], h, kv_x=enc,
                                    causal=False, use_rope=False)
        h = L.apply_norm(cfg, lp["norm3"], x)
        x = x + L.ffn_forward(cfg, lp["ffn"], h)
        return x, (k.astype(dtype), v.astype(dtype),
                   ck.astype(dtype), cv.astype(dtype))

    x, (ks, vs, cks, cvs) = L.layer_scan(body, x, params["dec_layers"])
    ks, vs, sp = L.fit_cache(ks, vs, S, clen, window, B)
    cache = {"k": ks, "v": vs, "cross_k": cks, "cross_v": cvs,
             "pos": jnp.full((B,), S, jnp.int32), "slot_pos": sp}
    x = L.apply_norm(cfg, params["final_norm"], x)
    logits = L.unembed(params["emb"], x[:, -1:])
    return logits[:, 0], cache


def decode_step(cfg: ModelConfig, params: Params, tokens: jax.Array,
                cache: Dict[str, jax.Array]):
    B = tokens.shape[0]
    x = L.embed(params["emb"], tokens)
    pos = cache["pos"]
    S = cache["k"].shape[2]
    slot = pos % S if cfg.sliding_window > 0 else pos
    slot_pos = cache["slot_pos"].at[jnp.arange(B), slot].set(pos)

    def body(x, inp):
        lp, kc, vc, ck, cv = inp
        h = L.apply_norm(cfg, lp["norm1"], x)
        o, kc, vc = L.attention_decode(cfg, lp["self_attn"], h, kc, vc, pos,
                                       slot_pos)
        x = x + o
        h = L.apply_norm(cfg, lp["norm2"], x)
        x = x + L.cross_attention_decode(cfg, lp["cross_attn"], h, ck, cv)
        h = L.apply_norm(cfg, lp["norm3"], x)
        x = x + L.ffn_forward(cfg, lp["ffn"], h)
        return x, (kc, vc)

    x, (ks, vs) = L.layer_scan(
        body, x, (params["dec_layers"], cache["k"], cache["v"],
                  cache["cross_k"], cache["cross_v"]))
    x = L.apply_norm(cfg, params["final_norm"], x)
    logits = L.unembed(params["emb"], x)[:, 0]
    return logits, dict(cache, k=ks, v=vs, pos=pos + 1, slot_pos=slot_pos)
