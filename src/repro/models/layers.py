"""Shared building blocks for all architectures.

Pure-JAX (no flax): parameters are nested dicts of jnp arrays created by
``init_*`` functions and consumed by the matching forward functions.

Conventions:
- activations compute in the parameter dtype; softmax / norms in float32.
- attention caches are dicts ``{"k": (B,S,Hkv,D), "v": ..., }`` per layer,
  stacked over layers by the model wrappers; absolute positions live in the
  top-level cache as ``pos (B,)`` and ``slot_pos (B,S)`` (supports both full
  caches and sliding-window ring buffers).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

Params = Dict[str, Any]

# ---------------------------------------------------------------- utilities


def layer_scan(body, carry, xs):
    """lax.scan over stacked layers, honoring the dry-run unroll flag
    (see repro.models.runtime_flags — XLA cost analysis needs unrolled
    loops for correct FLOP/byte counts)."""
    from repro.models import runtime_flags
    return jax.lax.scan(body, carry, xs,
                        unroll=runtime_flags.get_scan_unroll())


def _dense_init(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[0]
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def rms_norm(x: jax.Array, w: Optional[jax.Array], eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    if w is not None:
        y = y * w.astype(jnp.float32)
    return y.astype(dt)


def layer_norm(x: jax.Array, w: Optional[jax.Array], b: Optional[jax.Array],
               eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    if w is not None:
        y = y * w.astype(jnp.float32)
    if b is not None:
        y = y + b.astype(jnp.float32)
    return y.astype(dt)


def init_norm(cfg: ModelConfig, key, dtype) -> Params:
    if cfg.norm == "rmsnorm":
        return {"w": jnp.ones((cfg.d_model,), dtype)}
    if cfg.norm == "layernorm":
        return {"w": jnp.ones((cfg.d_model,), dtype),
                "b": jnp.zeros((cfg.d_model,), dtype)}
    return {}  # nonparametric (OLMo-style)


def apply_norm(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    if cfg.norm == "rmsnorm":
        return rms_norm(x, p["w"])
    if cfg.norm == "layernorm":
        return layer_norm(x, p["w"], p["b"])
    return layer_norm(x, None, None)  # nonparametric LN


# ---------------------------------------------------------------- RoPE


def rope_angles(positions: jax.Array, head_dim: int, theta: float) -> Tuple[jax.Array, jax.Array]:
    """positions (...,) -> cos/sin of shape (..., head_dim//2)."""
    half = head_dim // 2
    freqs = jnp.exp(-jnp.arange(0, half, dtype=jnp.float32) *
                    (math.log(theta) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x (..., n_heads, head_dim); cos/sin broadcastable to (..., 1, head_dim//2)."""
    dt = x.dtype
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    cos = cos[..., None, :]
    sin = sin[..., None, :]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                           axis=-1).astype(dt)


# ---------------------------------------------------------------- attention


def init_attention(cfg: ModelConfig, key, dtype, d_model: Optional[int] = None) -> Params:
    d = d_model or cfg.d_model
    hd = cfg.resolved_head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": _dense_init(kq, (d, cfg.n_heads * hd), dtype),
        "wk": _dense_init(kk, (d, cfg.n_kv_heads * hd), dtype),
        "wv": _dense_init(kv, (d, cfg.n_kv_heads * hd), dtype),
        "wo": _dense_init(ko, (cfg.n_heads * hd, d), dtype),
    }


def _gqa_scores_full(q, k, n_heads, n_kv):
    """q (B,S,H,D), k (B,T,Hkv,D) -> scores (B,H,S,T) with GQA broadcast."""
    group = n_heads // n_kv
    B, S, _, D = q.shape
    T = k.shape[1]
    qg = q.reshape(B, S, n_kv, group, D)
    s = jnp.einsum("bskgd,btkd->bkgst", qg, k,
                   preferred_element_type=jnp.float32)
    return s.reshape(B, n_kv * group, S, T)


def attention_forward(cfg: ModelConfig, p: Params, x: jax.Array, *,
                      positions: Optional[jax.Array] = None,
                      causal: bool = True,
                      kv_x: Optional[jax.Array] = None,
                      use_rope: bool = True,
                      prefix_len: int = 0,
                      return_kv: bool = False,
                      past_kv: Optional[Tuple[jax.Array, jax.Array]] = None):
    """Full-sequence (self or cross) attention.

    x (B,S,d). kv_x: source of K/V for cross-attention (B,T,d); None = self.
    positions: absolute positions (B,S) for RoPE; default arange.
    prefix_len: number of leading tokens (e.g. vision tokens) that every
    query may attend to bidirectionally (VLM prefix attention).
    return_kv: also return the (roped) K and V, e.g. for cache building.
    past_kv: (pk, pv) of shape (B, P, Hkv, D) — already-roped K/V of a
    prefix (chunked prefill / prefix caching); queries sit at absolute
    positions P.. and attend to the past causally.
    """
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    H, Hkv = cfg.n_heads, cfg.n_kv_heads
    src = x if kv_x is None else kv_x
    past_len = past_kv[0].shape[1] if past_kv is not None else 0
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    k = (src @ p["wk"]).reshape(B, src.shape[1], Hkv, hd)
    v = (src @ p["wv"]).reshape(B, src.shape[1], Hkv, hd)
    if use_rope and kv_x is None:
        if positions is None:
            positions = jnp.broadcast_to(
                past_len + jnp.arange(S)[None, :], (B, S))
        cos, sin = rope_angles(positions, hd, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    new_k, new_v = k, v
    if past_kv is not None:
        k = jnp.concatenate([past_kv[0].astype(k.dtype), k], axis=1)
        v = jnp.concatenate([past_kv[1].astype(v.dtype), v], axis=1)
    T = k.shape[1]
    if kv_x is None and S >= 2048:
        # long sequences: chunked online-softmax path (§Perf B1) — avoids
        # materializing the (S, T) score matrix
        out = _flash_attention_ref(q, k, v, causal=causal,
                                   window=cfg.sliding_window,
                                   prefix_len=prefix_len,
                                   n_heads=H, n_kv=Hkv,
                                   q_offset=past_len)
        out = out @ p["wo"]
        if return_kv:
            return out, new_k, new_v
        return out
    scores = _gqa_scores_full(q, k, H, Hkv) / math.sqrt(hd)   # (B,H,S,T)
    if causal and kv_x is None:
        qi = past_len + jnp.arange(S)[:, None]
        ki = jnp.arange(T)[None, :]
        mask = ki <= qi
        if cfg.sliding_window > 0:
            mask &= ki > qi - cfg.sliding_window
        if prefix_len > 0:
            mask |= ki < prefix_len
        scores = jnp.where(mask[None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)       # (B,H,S,T)
    group = H // Hkv
    wv = w.reshape(B, Hkv, group, S, T)
    o = jnp.einsum("bkgst,btkd->bskgd", wv, v).reshape(B, S, H * hd)
    out = o @ p["wo"]
    if return_kv:
        return out, new_k, new_v   # new tokens only (past excluded)
    return out


def fit_cache(ks: jax.Array, vs: jax.Array, total: int, clen: int,
              window: int, batch: int):
    """Fit stacked prefill K/V (L,B,total,Hkv,D) into a cache of length
    ``clen``: keep the last clen positions (ring-rolled when windowed) or
    right-pad with empty slots when clen > total. Returns (k, v, slot_pos)."""
    B = batch
    if clen > total:
        pad = clen - total
        ks = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        vs = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        sp = jnp.concatenate([jnp.arange(total), jnp.full((pad,), -1)])
        sp = jnp.broadcast_to(sp[None, :], (B, clen)).astype(jnp.int32)
        return ks, vs, sp
    start = total - clen
    ks = ks[:, :, -clen:]
    vs = vs[:, :, -clen:]
    sp = jnp.broadcast_to(jnp.arange(start, start + clen)[None, :],
                          (B, clen)).astype(jnp.int32)
    if window:
        shift = start % clen
        ks = jnp.roll(ks, shift, axis=2)
        vs = jnp.roll(vs, shift, axis=2)
        sp = jnp.roll(sp, shift, axis=1)
    return ks, vs, sp


def init_kv_cache(cfg: ModelConfig, batch: int, cache_len: int, n_layers: int,
                  dtype) -> Dict[str, jax.Array]:
    hd = cfg.resolved_head_dim
    return {
        "k": jnp.zeros((n_layers, batch, cache_len, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((n_layers, batch, cache_len, cfg.n_kv_heads, hd), dtype),
    }


def attention_decode(cfg: ModelConfig, p: Params, x: jax.Array,
                     k_cache: jax.Array, v_cache: jax.Array,
                     pos: jax.Array, slot_pos: jax.Array,
                     *, use_rope: bool = True) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode against a (possibly ring-buffer) KV cache.

    x (B,1,d); k_cache/v_cache (B,S,Hkv,D); pos (B,) absolute position of the
    new token; slot_pos (B,S) absolute position held by each slot (-1 empty,
    already including this step's write). Returns (out (B,1,d), k, v).
    """
    B = x.shape[0]
    hd = cfg.resolved_head_dim
    H, Hkv = cfg.n_heads, cfg.n_kv_heads
    S = k_cache.shape[1]
    q = (x @ p["wq"]).reshape(B, 1, H, hd)
    k = (x @ p["wk"]).reshape(B, 1, Hkv, hd)
    v = (x @ p["wv"]).reshape(B, 1, Hkv, hd)
    if use_rope:
        cos, sin = rope_angles(pos[:, None], hd, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    slot = pos % S if cfg.sliding_window > 0 else pos
    bidx = jnp.arange(B)
    k_cache = k_cache.at[bidx, slot].set(k[:, 0])
    v_cache = v_cache.at[bidx, slot].set(v[:, 0])
    # scores over the whole cache, masked by slot validity. f32 via the
    # dot's accumulator (preferred_element_type), NOT by casting inputs —
    # an input cast materializes an f32 copy of the whole K cache per
    # layer (§Perf C2: that copy was ~all of the decode memory term).
    group = H // Hkv
    qg = q.reshape(B, Hkv, group, hd)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache,
                   preferred_element_type=jnp.float32) / math.sqrt(hd)
    valid = slot_pos >= 0
    if cfg.sliding_window > 0:
        valid &= slot_pos[:, :] > (pos[:, None] - cfg.sliding_window)
    valid &= slot_pos <= pos[:, None]
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1).astype(v_cache.dtype)
    o = jnp.einsum("bkgs,bskd->bkgd", w, v_cache).reshape(B, 1, H * hd)
    return o @ p["wo"], k_cache, v_cache


def cross_attention_decode(cfg: ModelConfig, p: Params, x: jax.Array,
                           k_cache: jax.Array, v_cache: jax.Array) -> jax.Array:
    """One-token cross-attention against a fixed encoder KV (B,T,Hkv,D)."""
    B = x.shape[0]
    hd = cfg.resolved_head_dim
    H, Hkv = cfg.n_heads, cfg.n_kv_heads
    q = (x @ p["wq"]).reshape(B, 1, H, hd)
    group = H // Hkv
    qg = q.reshape(B, Hkv, group, hd)
    s = jnp.einsum("bkgd,btkd->bkgt", qg.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) / math.sqrt(hd)
    w = jax.nn.softmax(s, axis=-1).astype(v_cache.dtype)
    o = jnp.einsum("bkgt,btkd->bkgd", w, v_cache).reshape(B, 1, H * hd)
    return o @ p["wo"]


def _flash_attention_ref(q, k, v, *, causal: bool, window: int,
                         prefix_len: int, n_heads: int, n_kv: int,
                         block: int = 1024, q_offset: int = 0) -> jax.Array:
    """Chunked online-softmax attention (pure JAX; the lax.scan analogue of
    kernels/flash_prefill). Never materializes the (S, T) score matrix in
    HBM — §Perf B1: for yi-34b train_4k the full materialization made the
    memory roofline term 9x larger than the flash form. q (B,S,H,D);
    k/v (B,T,Hkv,D) (already roped). Returns (B,S,H*D)."""
    B, S, H, D = q.shape
    T = k.shape[1]
    g = n_heads // n_kv
    block = min(block, T)
    pad = (-T) % block
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nkb = (T + pad) // block
    qg = q.reshape(B, S, n_kv, g, D).transpose(0, 2, 3, 1, 4)  # (B,kv,g,S,D)
    qpos = q_offset + jnp.arange(S)

    def body(carry, j):
        m, l, acc = carry
        ks = jax.lax.dynamic_slice_in_dim(k, j * block, block, 1)
        vs = jax.lax.dynamic_slice_in_dim(v, j * block, block, 1)
        s = jnp.einsum("bkgsd,btkd->bkgst", qg, ks,
                       preferred_element_type=jnp.float32) / math.sqrt(D)
        kpos = j * block + jnp.arange(block)
        mask = kpos[None, :] < T
        if causal:
            cm = kpos[None, :] <= qpos[:, None]
            if window > 0:
                cm &= kpos[None, :] > qpos[:, None] - window
            if prefix_len > 0:
                cm |= kpos[None, :] < prefix_len
            mask = mask & cm
        s = jnp.where(mask[None, None, None], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = alpha * l + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bkgst,btkd->bkgsd", p.astype(vs.dtype), vs).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, n_kv, g, S), -1e30, jnp.float32)
    l0 = jnp.zeros((B, n_kv, g, S), jnp.float32)
    a0 = jnp.zeros((B, n_kv, g, S, D), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), jnp.arange(nkb))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, S, H * D).astype(q.dtype)


# ---------------------------------------------------------------- FFN


def init_ffn(cfg: ModelConfig, key, dtype, d_ff: Optional[int] = None,
             d_model: Optional[int] = None) -> Params:
    d = d_model or cfg.d_model
    f = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.ffn == "swiglu":
        return {"w_gate": _dense_init(k1, (d, f), dtype),
                "w_up": _dense_init(k3, (d, f), dtype),
                "w_down": _dense_init(k2, (f, d), dtype)}
    return {"w_up": _dense_init(k1, (d, f), dtype),
            "w_down": _dense_init(k2, (f, d), dtype)}


def ffn_forward(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    if cfg.ffn == "swiglu":
        return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]
    return jax.nn.gelu(x @ p["w_up"]) @ p["w_down"]


# ---------------------------------------------------------------- embeddings


def init_embeddings(cfg: ModelConfig, key, dtype) -> Params:
    k1, k2 = jax.random.split(key)
    p = {"tok": _dense_init(k1, (cfg.vocab_size, cfg.d_model), dtype, scale=0.02)}
    if not cfg.tie_embeddings:
        p["head"] = _dense_init(k2, (cfg.d_model, cfg.vocab_size), dtype)
    return p


def embed(p: Params, tokens: jax.Array) -> jax.Array:
    return p["tok"][tokens]


def unembed(p: Params, x: jax.Array) -> jax.Array:
    if "head" in p:
        return x @ p["head"]
    return x @ p["tok"].T
