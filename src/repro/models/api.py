"""Unified model API over all architecture families.

``Model(cfg)`` exposes:
  init(key, dtype)                 -> params
  forward(params, batch)           -> (logits, aux_loss)
  loss(params, batch)              -> scalar causal-LM loss (+ MoE aux)
  prefill(params, batch)           -> (last_logits, cache)
  decode_step(params, tokens, cache)-> (logits, cache)
  init_cache(batch, cache_len)     -> zeroed cache pytree
  example_batch(batch, seq, key)   -> random batch with the right modalities

``batch`` is a dict: always ``tokens (B,S) int32``; plus ``frames`` for audio
(stub frame embeddings) and ``vision`` for VLM (stub patch embeddings).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec, hybrid, mamba_model, transformer

Params = Dict[str, Any]
Batch = Dict[str, jax.Array]

_FAMILY = {
    "dense": transformer,
    "moe": transformer,
    "vlm": transformer,
    "ssm": mamba_model,
    "hybrid": hybrid,
    "audio": encdec,
}


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self._m = _FAMILY[cfg.arch_type]

    # ------------------------------------------------------------ params
    def init(self, key, dtype=None) -> Params:
        return self._m.init_params(self.cfg, key, dtype=dtype)

    # ------------------------------------------------------------ forward
    def forward(self, params: Params, batch: Batch, *, remat: bool = False):
        kw = {}
        if self.cfg.arch_type == "vlm":
            kw["vision_embeds"] = batch["vision"]
        if self.cfg.arch_type == "audio":
            kw["frames"] = batch["frames"]
        return self._m.forward(self.cfg, params, batch["tokens"],
                               remat=remat, **kw)

    def loss(self, params: Params, batch: Batch, *, remat: bool = False) -> jax.Array:
        logits, aux = self.forward(params, batch, remat=remat)
        tokens = batch["tokens"]
        tgt = tokens[:, 1:]
        lg = logits[:, :-1].astype(jnp.float32)
        lse = jax.nn.logsumexp(lg, axis=-1)
        true = jnp.take_along_axis(lg, tgt[..., None], axis=-1)[..., 0]
        mask = batch.get("loss_mask")
        nll = lse - true
        if mask is not None:
            m = mask[:, 1:].astype(jnp.float32)
            return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0) + aux
        return jnp.mean(nll) + aux

    # ------------------------------------------------------------ serving
    def init_cache(self, batch: int, cache_len: int, dtype=None):
        dtype = dtype or jnp.dtype(self.cfg.dtype)
        return self._m.init_cache(self.cfg, batch, cache_len, dtype)

    def prefill(self, params: Params, batch: Batch, *,
                cache_len: Optional[int] = None, dtype=None,
                past_cache=None):
        kw = {}
        if self.cfg.arch_type == "vlm":
            kw["vision_embeds"] = batch["vision"]
        if self.cfg.arch_type == "audio":
            kw["frames"] = batch["frames"]
        if past_cache is not None:
            if self.cfg.arch_type not in ("dense", "moe", "vlm"):
                raise NotImplementedError(
                    "chunked prefill: transformer family only")
            kw["past_cache"] = past_cache
        return self._m.prefill(self.cfg, params, batch["tokens"],
                               cache_len=cache_len, dtype=dtype, **kw)

    def decode_step(self, params: Params, tokens: jax.Array, cache):
        return self._m.decode_step(self.cfg, params, tokens, cache)

    # ------------------------------------------------------------ inputs
    def example_batch(self, batch: int, seq: int, key=None,
                      dtype=None) -> Batch:
        cfg = self.cfg
        dtype = dtype or jnp.dtype(cfg.dtype)
        key = key if key is not None else jax.random.PRNGKey(0)
        k1, k2 = jax.random.split(key)
        out: Batch = {"tokens": jax.random.randint(
            k1, (batch, seq), 0, cfg.vocab_size, dtype=jnp.int32)}
        if cfg.arch_type == "audio":
            out["frames"] = jax.random.normal(
                k2, (batch, cfg.enc_seq, cfg.d_model)).astype(dtype)
        if cfg.arch_type == "vlm":
            out["vision"] = jax.random.normal(
                k2, (batch, cfg.n_vision_tokens, cfg.d_model)).astype(dtype)
        return out


def get_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
