"""Process-wide model-execution flags.

``scan_unroll``: unroll factor for the over-layers lax.scan. The default (1)
keeps HLO compact for smoke tests and real serving. The dry-run sets this to
True (full unroll) because XLA's cost analysis does not multiply while-loop
body costs by trip count — rooflines derived from a scanned module would
undercount FLOPs/bytes by a factor of n_layers.
"""
from __future__ import annotations

from typing import Union

scan_unroll: Union[int, bool] = 1

# Mesh for model-internal shard_map blocks (MoE combine-then-reduce, §Perf
# A4). None = single-device execution (smoke tests, the real CPU engine).
mesh = None


def set_scan_unroll(v: Union[int, bool]) -> None:
    global scan_unroll
    scan_unroll = v


def get_scan_unroll() -> Union[int, bool]:
    return scan_unroll


def set_mesh(m) -> None:
    global mesh
    mesh = m


def get_mesh():
    return mesh
