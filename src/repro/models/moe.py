"""Mixture-of-Experts FFN with capacity-based token dispatch.

Dispatch is gather/scatter based (MaxText-style), not the O(T*E*C) one-hot
einsum: tokens are routed top-k, assigned a position inside their expert via
a cumulative-sum rank, dropped beyond capacity, gathered into an (E, C, d)
buffer, run through batched expert FFNs on the MXU, and scattered back.
With experts sharded on the `model` mesh axis this lowers to all-to-all
style collectives, which is exactly the term the roofline analysis tracks
for MoE architectures.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import _dense_init

Params = Dict[str, jax.Array]


def init_moe(cfg: ModelConfig, key, dtype) -> Params:
    d = cfg.d_model
    m = cfg.moe
    kr, k1, k2, k3, ks = jax.random.split(key, 5)
    p = {
        "router": _dense_init(kr, (d, m.n_experts), jnp.float32),
        "w_gate": _dense_init(k1, (m.n_experts, d, m.d_ff), dtype),
        "w_up": _dense_init(k2, (m.n_experts, d, m.d_ff), dtype),
        "w_down": _dense_init(k3, (m.n_experts, m.d_ff, d), dtype),
    }
    if m.n_shared_experts:
        f_sh = m.n_shared_experts * m.d_ff
        ka, kb, kc = jax.random.split(ks, 3)
        p["shared"] = {"w_gate": _dense_init(ka, (d, f_sh), dtype),
                       "w_up": _dense_init(kb, (d, f_sh), dtype),
                       "w_down": _dense_init(kc, (f_sh, d), dtype)}
    return p


def expert_capacity(cfg: ModelConfig, n_tokens: int) -> int:
    m = cfg.moe
    c = math.ceil(n_tokens * m.experts_per_token / m.n_experts * m.capacity_factor)
    return max(8, -(-c // 8) * 8)  # round up to a multiple of 8


def moe_forward(cfg: ModelConfig, p: Params, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x (T, d) -> (y (T, d), aux_load_balance_loss)."""
    m = cfg.moe
    T, d = x.shape
    E, K = m.n_experts, m.experts_per_token
    C = expert_capacity(cfg, T)

    logits = x.astype(jnp.float32) @ p["router"]            # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, K)                     # (T, K)
    gate = gate / jnp.sum(gate, axis=-1, keepdims=True)

    flat_e = idx.reshape(-1)                                # (T*K,)
    flat_gate = gate.reshape(-1)
    tok_id = jnp.repeat(jnp.arange(T), K)

    oh = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)         # (T*K, E)
    pos_in_e = jnp.sum(jnp.cumsum(oh, axis=0) * oh, axis=-1) - 1
    keep = pos_in_e < C
    dest = jnp.where(keep, flat_e * C + pos_in_e, E * C)    # E*C = drop slot

    # scatter token ids into (E*C,) buffer (+1 drop slot)
    buf_tok = jnp.zeros((E * C + 1,), jnp.int32).at[dest].set(tok_id, mode="drop")
    buf_fill = jnp.zeros((E * C + 1,), jnp.bool_).at[dest].set(keep, mode="drop")
    xe = x[buf_tok[:-1]] * buf_fill[:-1, None].astype(x.dtype)   # (E*C, d)
    xe = xe.reshape(E, C, d)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", xe, p["w_up"])
    out_e = jnp.einsum("ecf,efd->ecd", h, p["w_down"])      # (E, C, d)

    out_flat = jnp.concatenate(
        [out_e.reshape(E * C, d), jnp.zeros((1, d), out_e.dtype)], axis=0)
    y_assign = out_flat[dest] * (flat_gate * keep).astype(x.dtype)[:, None]
    y = jnp.sum(y_assign.reshape(T, K, d), axis=1)

    if "shared" in p:
        sh = p["shared"]
        y = y + (jax.nn.silu(x @ sh["w_gate"]) * (x @ sh["w_up"])) @ sh["w_down"]

    # Switch-style load balance auxiliary loss
    frac_tokens = jnp.mean(jax.nn.one_hot(idx, E, dtype=jnp.float32), axis=(0, 1))
    frac_prob = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(frac_tokens * frac_prob) * m.router_aux_loss
    return y, aux


def _shard(x: jax.Array, *spec) -> jax.Array:
    """Best-effort sharding constraint: a no-op when no mesh is in context
    (single-device smoke tests / the real CPU engine)."""
    try:
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.PartitionSpec(*spec))
    except (RuntimeError, ValueError, TypeError):
        return x


def _ambient_mesh():
    from repro.models import runtime_flags
    m = runtime_flags.get_mesh()
    if m is not None and "model" in m.axis_names:
        return m
    return None


def _expert_block(fn, x, buf_tok, buf_fill, dest, gate_w, wg, wu, wd):
    """Run the dispatch->FFN->combine block, under shard_map over the model
    axis when a mesh is in context (expert weights f-sharded; the combined
    (B,S,d) output is psum'd — combine-then-reduce, §Perf A4)."""
    from jax.sharding import PartitionSpec as P
    mesh = _ambient_mesh()
    if mesh is None:
        return fn(x, buf_tok, buf_fill, dest, gate_w, wg, wu, wd)
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bspec = batch_axes if len(batch_axes) > 1 else (batch_axes or (None,))[0]
    if isinstance(bspec, tuple):
        bspec = bspec
    data3 = P(bspec, None, None)
    data2 = P(bspec, None)
    wcol = P(None, None, "model")   # (E, d, f) sharded on f
    wrow = P(None, "model", None)   # (E, f, d) sharded on f

    def inner(x_, bt_, bf_, de_, gw_, wg_, wu_, wd_):
        y_part = fn(x_, bt_, bf_, de_, gw_, wg_, wu_, wd_)
        return jax.lax.psum(y_part, "model")

    return jax.shard_map(
        inner, mesh=mesh,
        in_specs=(data3, data2, data2, data2, data2, wcol, wcol, wrow),
        out_specs=data3,
        check_vma=False,
    )(x, buf_tok, buf_fill, dest, gate_w, wg, wu, wd)


def moe_forward_batched(cfg: ModelConfig, p: Params, x: jax.Array):
    """Per-batch-row dispatch, batch-dim native (§Perf A1+A2).

    A1: flat (B*S)-token dispatch builds (E, C_global, d) gather buffers
    whose token indices mix data shards, so GSPMD replicates the gathers —
    280 GiB/device temp and a 147 s collective term for qwen2-moe train_4k.
    Dispatching within each batch row keeps every buffer a (B, ...) tensor.
    A2: vmap alone was not enough — GSPMD still chose to all-gather the
    (B, E, C, d) buffers over batch — so the dispatch is written batch-
    native with explicit sharding constraints pinning B to the data axis.

    x (B, S, d) -> (y (B, S, d), aux (,))
    """
    m = cfg.moe
    B, S, d = x.shape
    E, K = m.n_experts, m.experts_per_token
    C = expert_capacity(cfg, S)
    BSPEC = ("data",)   # batch stays on the data axis throughout

    # §Perf A3: router matmul in the activation dtype — f32 router weights
    # promote the backward residual stream to f32, doubling every per-layer
    # gradient all-reduce. Softmax still runs in f32.
    logits = (x @ p["router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, K)                       # (B, S, K)
    gate = gate / jnp.sum(gate, axis=-1, keepdims=True)

    flat_e = idx.reshape(B, S * K)
    flat_gate = gate.reshape(B, S * K)
    tok_id = jnp.broadcast_to(
        jnp.repeat(jnp.arange(S), K)[None], (B, S * K))

    oh = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)           # (B, S*K, E)
    oh = _shard(oh, *BSPEC, None, None)
    pos_in_e = jnp.sum(jnp.cumsum(oh, axis=1) * oh, axis=-1) - 1
    keep = pos_in_e < C
    dest = jnp.where(keep, flat_e * C + pos_in_e, E * C)      # (B, S*K)

    bidx = jnp.arange(B)[:, None]
    buf_tok = jnp.zeros((B, E * C + 1), jnp.int32) \
        .at[bidx, dest].set(tok_id, mode="drop")
    buf_fill = jnp.zeros((B, E * C + 1), jnp.bool_) \
        .at[bidx, dest].set(keep, mode="drop")
    gate_w = (flat_gate * keep).astype(x.dtype)

    def experts(x_, buf_tok_, buf_fill_, dest_, gate_w_, wg, wu, wd):
        """Dispatch -> expert FFN -> combine. Runs either plainly (no mesh)
        or inside shard_map over the model axis with f-sharded expert
        weights; the token combine happens on the PARTIAL w_down outputs so
        only the (B,S,d) result is psum'd — not the 5x-larger (B,E,C,d)
        capacity buffer (§Perf A4, combine-then-reduce). All dims derived
        from the (possibly shard-local) arguments."""
        b_, s_, d_ = x_.shape
        e_ = wg.shape[0]
        c_ = (buf_tok_.shape[1] - 1) // e_
        k_ = dest_.shape[1] // s_
        xe = jnp.take_along_axis(x_, buf_tok_[:, :-1, None], axis=1)
        xe = xe * buf_fill_[:, :-1, None].astype(x_.dtype)
        xe = xe.reshape(b_, e_, c_, d_)
        h = jax.nn.silu(jnp.einsum("becd,edf->becf", xe, wg))
        h = h * jnp.einsum("becd,edf->becf", xe, wu)
        out_e = jnp.einsum("becf,efd->becd", h, wd)   # partial over f-shards
        out_flat = jnp.concatenate(
            [out_e.reshape(b_, e_ * c_, d_),
             jnp.zeros((b_, 1, d_), out_e.dtype)], axis=1)
        y_assign = jnp.take_along_axis(out_flat, dest_[:, :, None], axis=1)
        y_assign = y_assign * gate_w_[:, :, None]
        return jnp.sum(y_assign.reshape(b_, s_, k_, d_), axis=2)

    y = _expert_block(experts, x, buf_tok, buf_fill, dest, gate_w,
                      p["w_gate"], p["w_up"], p["w_down"])

    if "shared" in p:
        sh = p["shared"]
        y = y + (jax.nn.silu(x @ sh["w_gate"]) * (x @ sh["w_up"])) @ sh["w_down"]

    frac_tokens = jnp.mean(jax.nn.one_hot(idx, E, dtype=jnp.float32),
                           axis=(0, 1, 2))
    frac_prob = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(frac_tokens * frac_prob) * m.router_aux_loss
    return y, aux
