"""Decoder-only transformer stack shared by dense, MoE and VLM architectures.

Layers are stacked (leading axis = n_layers) and executed with
``jax.lax.scan`` so the lowered HLO stays compact even for 60-80 layer
configurations — essential for the 40-config dry-run matrix.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.moe import init_moe, moe_forward, moe_forward_batched

Params = Dict[str, Any]


def init_layer(cfg: ModelConfig, key, dtype) -> Params:
    ka, kf, kn1, kn2 = jax.random.split(key, 4)
    p = {
        "attn": L.init_attention(cfg, ka, dtype),
        "norm1": L.init_norm(cfg, kn1, dtype),
        "norm2": L.init_norm(cfg, kn2, dtype),
    }
    if cfg.is_moe:
        p["moe"] = init_moe(cfg, kf, dtype)
    else:
        p["ffn"] = L.init_ffn(cfg, kf, dtype)
    return p


def init_params(cfg: ModelConfig, key, dtype=None) -> Params:
    dtype = dtype or jnp.dtype(cfg.dtype)
    ke, kl, kn = jax.random.split(key, 3)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    stacked = jax.vmap(lambda k: init_layer(cfg, k, dtype))(layer_keys)
    return {
        "emb": L.init_embeddings(cfg, ke, dtype),
        "layers": stacked,
        "final_norm": L.init_norm(cfg, kn, dtype),
    }


def _layer_forward(cfg: ModelConfig, lp: Params, x: jax.Array,
                   positions: jax.Array, prefix_len: int) -> Tuple[jax.Array, jax.Array]:
    h = L.apply_norm(cfg, lp["norm1"], x)
    x = x + L.attention_forward(cfg, lp["attn"], h, positions=positions,
                                prefix_len=prefix_len)
    h = L.apply_norm(cfg, lp["norm2"], x)
    if cfg.is_moe:
        # per-batch-row dispatch keeps MoE buffers data-sharded (§Perf A1)
        y, aux = moe_forward_batched(cfg, lp["moe"], h)
        return x + y, aux
    return x + L.ffn_forward(cfg, lp["ffn"], h), jnp.zeros((), jnp.float32)


def forward(cfg: ModelConfig, params: Params, tokens: jax.Array, *,
            vision_embeds: Optional[jax.Array] = None,
            remat: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence forward. Returns (logits (B,S,V), aux_loss).

    For VLM configs, ``vision_embeds`` (B, n_vis, d) is prepended to the
    token embeddings (stub frontend per the task carve-out); logits are
    returned for the text positions only.
    """
    x = L.embed(params["emb"], tokens)
    prefix_len = 0
    if vision_embeds is not None:
        x = jnp.concatenate([vision_embeds.astype(x.dtype), x], axis=1)
        prefix_len = vision_embeds.shape[1]
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))

    def body(carry, lp):
        x, aux = carry
        x, a = _layer_forward(cfg, lp, x, positions, prefix_len)
        return (x, aux + a), None

    step = jax.checkpoint(body) if remat else body
    (x, aux), _ = L.layer_scan(step, (x, jnp.zeros((), jnp.float32)),
                               params["layers"])
    x = L.apply_norm(cfg, params["final_norm"], x)
    if prefix_len:
        x = x[:, prefix_len:]
    return L.unembed(params["emb"], x), aux


def init_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype) -> Dict[str, jax.Array]:
    c = L.init_kv_cache(cfg, batch, cache_len, cfg.n_layers, dtype)
    c["pos"] = jnp.zeros((batch,), jnp.int32)
    c["slot_pos"] = jnp.full((batch, cache_len), -1, jnp.int32)
    return c


def prefill(cfg: ModelConfig, params: Params, tokens: jax.Array, *,
            cache_len: Optional[int] = None,
            vision_embeds: Optional[jax.Array] = None,
            past_cache: Optional[Dict[str, jax.Array]] = None,
            dtype=None) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Run the prompt, build the KV cache, return last-position logits.

    Uses the full-sequence path and stores the (roped) K/V of the last
    ``cache_len`` positions. With a sliding window the cache is laid out as
    the ring buffer the decode step expects (slot = pos % cache_len, which
    for a contiguous tail is a plain roll).

    ``past_cache``: an existing (non-windowed, fully-filled) cache to
    continue from — the chunked-prefill / prefix-caching path: only the new
    tokens are computed; the returned cache covers past + new.
    """
    dtype = dtype or jnp.dtype(cfg.dtype)
    B, S = tokens.shape
    n_vis = vision_embeds.shape[1] if vision_embeds is not None else 0
    window = cfg.sliding_window or 0
    past_len = 0
    if past_cache is not None:
        assert window == 0, "chunked prefill assumes a non-windowed cache"
        assert n_vis == 0, "vision prefix must be in the first chunk"
        past_len = int(past_cache["k"].shape[2])
    total = S + n_vis
    full_len = past_len + total
    clen = cache_len or (min(full_len, window) if window else full_len)

    x = L.embed(params["emb"], tokens)
    if vision_embeds is not None:
        x = jnp.concatenate([vision_embeds.astype(x.dtype), x], axis=1)
    positions = jnp.broadcast_to(
        past_len + jnp.arange(total)[None, :], (B, total))

    def body(carry, inp):
        if past_cache is not None:
            lp, pk, pv = inp
            past = (pk, pv)
        else:
            lp, past = inp, None
        x, aux = carry
        h = L.apply_norm(cfg, lp["norm1"], x)
        o, k, v = L.attention_forward(cfg, lp["attn"], h, positions=positions,
                                      prefix_len=n_vis, return_kv=True,
                                      past_kv=past)
        x = x + o
        h = L.apply_norm(cfg, lp["norm2"], x)
        if cfg.is_moe:
            y, a = moe_forward_batched(cfg, lp["moe"], h)
            x = x + y
            aux = aux + a
        else:
            x = x + L.ffn_forward(cfg, lp["ffn"], h)
        return (x, aux), (k.astype(dtype), v.astype(dtype))

    xs = params["layers"] if past_cache is None else \
        (params["layers"], past_cache["k"], past_cache["v"])
    (x, _), (ks, vs) = L.layer_scan(
        body, (x, jnp.zeros((), jnp.float32)), xs)

    if past_cache is not None:
        ks = jnp.concatenate([past_cache["k"].astype(dtype), ks], axis=2)
        vs = jnp.concatenate([past_cache["v"].astype(dtype), vs], axis=2)
    ks, vs, sp = L.fit_cache(ks, vs, full_len, clen, window, B)
    cache = {"k": ks, "v": vs,
             "pos": jnp.full((B,), full_len, jnp.int32),
             "slot_pos": sp}
    x = L.apply_norm(cfg, params["final_norm"], x)
    logits = L.unembed(params["emb"], x[:, -1:])
    return logits[:, 0], cache


def decode_step(cfg: ModelConfig, params: Params, tokens: jax.Array,
                cache: Dict[str, jax.Array]) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One decode step. tokens (B,1) -> logits (B,V), updated cache."""
    B = tokens.shape[0]
    x = L.embed(params["emb"], tokens)
    pos = cache["pos"]
    S = cache["k"].shape[2]
    slot = pos % S if cfg.sliding_window > 0 else pos
    slot_pos = cache["slot_pos"].at[jnp.arange(B), slot].set(pos)

    def body(carry, inp):
        x, aux = carry
        lp, kc, vc = inp
        h = L.apply_norm(cfg, lp["norm1"], x)
        o, kc, vc = L.attention_decode(cfg, lp["attn"], h, kc, vc, pos, slot_pos)
        x = x + o
        h = L.apply_norm(cfg, lp["norm2"], x)
        if cfg.is_moe:
            y, a = moe_forward(cfg, lp["moe"], h[:, 0])
            x = x + y[:, None]
            aux = aux + a
        else:
            x = x + L.ffn_forward(cfg, lp["ffn"], h)
        return (x, aux), (kc, vc)

    (x, _), (ks, vs) = L.layer_scan(
        body, (x, jnp.zeros((), jnp.float32)),
        (params["layers"], cache["k"], cache["v"]))
    x = L.apply_norm(cfg, params["final_norm"], x)
    logits = L.unembed(params["emb"], x)[:, 0]
    new_cache = dict(cache, k=ks, v=vs, pos=pos + 1, slot_pos=slot_pos)
    return logits, new_cache
