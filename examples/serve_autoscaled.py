"""End-to-end driver: REAL JAX serving with Chiron's local autoscaler.

  PYTHONPATH=src python examples/serve_autoscaled.py [--arch mamba2-1.3b]

A continuous-batching engine serves a mixed interactive+batch workload on
the reduced model; the local autoscaler closes the loop on measured ITL
and throughput, and an interactive request preempts a batch request on the
(mixed) instance — the full Chiron mixed-instance story on one box.
"""
import argparse
import time

import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.core.backpressure import LocalMetrics
from repro.core.local_autoscaler import LocalAutoscaler
from repro.serving.engine import Engine
from repro.serving.request import RequestState, make_batch, make_interactive

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="granite-8b")
args = ap.parse_args()

cfg = get_smoke_config(args.arch)
eng = Engine(cfg, max_slots=6, max_len=128, dtype=jnp.float32)
scaler = LocalAutoscaler(itl_slo=1.0, init_batch=2, max_batch=6)

reqs = ([make_batch(16, 40) for _ in range(4)] +
        [make_interactive(12, 10) for _ in range(4)])
for r in reqs[:4]:
    eng.submit(r)

t0 = time.monotonic()
step = 0
while eng.waiting or eng.n_active or step == 0:
    stats = eng.step()
    step += 1
    if step == 6:   # interactive burst mid-run -> preemption path
        for r in reqs[4:]:
            eng.submit(r)
        print(f"step {step}: interactive burst submitted")
    if stats.preempted:
        print(f"step {step}: PREEMPTED batch request "
              f"{[r.req_id for r in stats.preempted]} (KV saved to host)")
        for r in stats.preempted:
            eng.submit(r)   # back into the queue; resumes from saved KV
    if step % 5 == 0 and stats.n_active:
        bs = scaler.update(LocalMetrics(stats.itl, stats.throughput or 1.0,
                                        itl_slo=1.0))
        eng.set_max_batch_size(bs)
        print(f"step {step:3d}: active={stats.n_active} "
              f"itl={stats.itl*1e3:5.0f}ms thr={stats.throughput:6.1f} tok/s "
              f"max_batch={bs}")
    if step > 400:
        break

wall = time.monotonic() - t0
done = [r for r in reqs if r.state == RequestState.FINISHED]
toks = sum(r.tokens_generated for r in reqs)
print(f"\n{len(done)}/{len(reqs)} requests served, {toks} tokens in "
      f"{wall:.1f}s; preemptions: {sum(r.preemptions for r in reqs)}; "
      f"ITL SLO met: {sum(r.itl_met() for r in done)}/{len(done)}")
assert len(done) == len(reqs)
