"""End-to-end training driver: ~100M-parameter model, few hundred steps.

  PYTHONPATH=src python examples/train_tiny.py [--steps 200]

Exercises the full training substrate (model stack, AdamW, remat option,
checkpointing) on CPU with an OLMo-family config scaled to ~100M params.
"""
import argparse

from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()
    import sys
    sys.argv = ["train", "--arch", "olmo-1b", "--steps", str(args.steps),
                "--batch", "8", "--seq", "128", "--lr", "1e-3",
                "--checkpoint", "/tmp/repro_tiny_ckpt"]
    # ~100M variant of the olmo family
    from repro.configs import olmo_1b
    orig = olmo_1b.smoke_config
    olmo_1b.smoke_config = lambda: olmo_1b.CONFIG.with_(
        n_layers=8, d_model=512, n_heads=8, n_kv_heads=8, d_ff=2048,
        vocab_size=32000)
    try:
        train_mod.main()
    finally:
        olmo_1b.smoke_config = orig


if __name__ == "__main__":
    main()
