"""Walkthrough: the columnar trace plane + the event-driven simulator.

Run:  PYTHONPATH=src python examples/scenario_sweep.py

1. lists the registered scenarios,
2. runs two of them end-to-end on the event-driven engine straight from
   the columnar ``Trace`` (lazy request materialization),
3. runs the multi-model fleet (per-model SLO attainment on one shared
   chip budget) and the failure-injection scenario,
4. round-trips a trace through a CSV file (``trace_replay`` style),
5. shows the engine dispatch (`simulate(..., engine=...)`) and the
   event-vs-fixed-tick speedup on a small backlog drain.

The full benchmark (100k-request traces, seed-baseline comparison,
``BENCH_scenarios.json``) lives in ``benchmarks/scenario_sweep.py``.
"""
import os
import tempfile
import time

from repro.sim.cluster import SimCluster
from repro.sim.controllers import ChironController
from repro.sim.scenarios import SCENARIOS, build_trace
from repro.sim.simulator import default_perf_factory, simulate
from repro.sim.trace_io import load_trace, save_trace


def _controller(kw):
    return ChironController(models=kw["models"]) if "models" in kw \
        else ChironController()


def main():
    print("registered scenarios:")
    for name, sc in sorted(SCENARIOS.items()):
        print(f"  {name:18s} {sc.description}")

    for name in ("diurnal", "multi_tenant_slo", "multi_model_fleet",
                 "instance_failures"):
        trace, kw = build_trace(name, n_requests=1200, seed=0)
        cluster = SimCluster(default_perf_factory(), max_chips=200)
        t0 = time.perf_counter()
        res = simulate(trace, _controller(kw), cluster,
                       max_time=kw["max_time"], warm_start=2,
                       failures=kw.get("failures"))
        wall = time.perf_counter() - t0
        s = res.summary()
        print(f"\n{name}: {trace.n} requests in {wall:.2f}s wall "
              f"({res.duration:.0f}s simulated)")
        print(f"  slo_attainment={s['slo_attainment']:.3f} "
              f"gpu_hours={s['gpu_hours']:.2f} "
              f"peak_chips={s['peak_chips']} "
              f"hysteresis={s['hysteresis']:.2f}")
        per_model = {k.split(':', 1)[1]: v for k, v in s.items()
                     if k.startswith('slo_model:')}
        if per_model:
            print(f"  per-model SLO: "
                  + " ".join(f"{m}={v:.3f}" for m, v in per_model.items()))
        if res.failures:
            print(f"  injected failures survived: {res.failures}")

    # trace replay: save a scenario to CSV, load it back, run the replay
    trace, kw = build_trace("trace_replay", n_requests=2000, seed=1)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "trace.csv")
        save_trace(trace, path)
        replay = load_trace(path)
        res = simulate(replay, ChironController(),
                       SimCluster(default_perf_factory(), max_chips=200),
                       max_time=kw["max_time"], warm_start=2)
    print(f"\ntrace_replay via CSV: {replay.n} requests round-tripped, "
          f"slo={res.slo_attainment():.3f}")

    # engine dispatch: same trace, event core vs fixed-tick reference
    walls = {}
    for engine in ("event", "fixed"):
        trace_i, kw = build_trace("backlog_drain", n_requests=3000, seed=1)
        cluster = SimCluster(default_perf_factory(), max_chips=200)
        t0 = time.perf_counter()
        simulate(trace_i, ChironController(), cluster,
                 max_time=kw["max_time"], warm_start=2, engine=engine)
        walls[engine] = time.perf_counter() - t0
    print(f"\nbacklog_drain x3000: event {walls['event']:.2f}s vs "
          f"fixed-tick {walls['fixed']:.2f}s "
          f"({walls['fixed'] / walls['event']:.1f}x)")


if __name__ == "__main__":
    main()
