"""Walkthrough: the scenario library + the event-driven simulator core.

Run:  PYTHONPATH=src python examples/scenario_sweep.py

1. lists the registered scenarios,
2. runs two of them end-to-end on the event-driven engine,
3. shows the engine dispatch (`simulate(..., engine=...)`) and the
   event-vs-fixed-tick speedup on a small backlog drain.

The full benchmark (100k-request traces, seed-baseline comparison) lives
in ``benchmarks/scenario_sweep.py``.
"""
import time

from repro.sim.cluster import SimCluster
from repro.sim.controllers import ChironController
from repro.sim.scenarios import SCENARIOS, build
from repro.sim.simulator import default_perf_factory, simulate


def main():
    print("registered scenarios:")
    for name, sc in sorted(SCENARIOS.items()):
        print(f"  {name:18s} {sc.description}")

    for name in ("diurnal", "multi_tenant_slo"):
        reqs, kw = build(name, n_requests=1200, seed=0)
        cluster = SimCluster(default_perf_factory(), max_chips=200)
        t0 = time.perf_counter()
        res = simulate(reqs, ChironController(), cluster,
                       max_time=kw["max_time"], warm_start=2)
        wall = time.perf_counter() - t0
        s = res.summary()
        print(f"\n{name}: {len(reqs)} requests in {wall:.2f}s wall "
              f"({res.duration:.0f}s simulated)")
        print(f"  slo_attainment={s['slo_attainment']:.3f} "
              f"gpu_hours={s['gpu_hours']:.2f} "
              f"peak_chips={s['peak_chips']} "
              f"hysteresis={s['hysteresis']:.2f}")

    # engine dispatch: same trace, event core vs fixed-tick reference
    reqs, kw = build("backlog_drain", n_requests=3000, seed=1)
    walls = {}
    for engine in ("event", "fixed"):
        reqs_i, _ = build("backlog_drain", n_requests=3000, seed=1)
        cluster = SimCluster(default_perf_factory(), max_chips=200)
        t0 = time.perf_counter()
        simulate(reqs_i, ChironController(), cluster,
                 max_time=kw["max_time"], warm_start=2, engine=engine)
        walls[engine] = time.perf_counter() - t0
    print(f"\nbacklog_drain x3000: event {walls['event']:.2f}s vs "
          f"fixed-tick {walls['fixed']:.2f}s "
          f"({walls['fixed'] / walls['event']:.1f}x)")


if __name__ == "__main__":
    main()
