"""Quickstart: the public API in five minutes.

  PYTHONPATH=src python examples/quickstart.py

1. pick an assigned architecture config (full or reduced),
2. build the model, run a forward pass,
3. prefill a prompt and decode a few tokens through the KV cache,
4. score a batch (training loss),
5. inspect Chiron's autoscaler on synthetic metrics.
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke_config, list_archs
from repro.core.backpressure import LocalMetrics
from repro.core.local_autoscaler import LocalAutoscaler
from repro.models import get_model

print("assigned architectures:", ", ".join(list_archs()))

# full config (what the dry-run lowers) vs reduced config (CPU-runnable)
full = get_config("granite-8b")
print(f"\ngranite-8b full: {full.n_layers}L d={full.d_model} "
      f"params={full.param_count()/1e9:.1f}B [{full.source}]")

cfg = get_smoke_config("granite-8b")
model = get_model(cfg)
params = model.init(jax.random.PRNGKey(0), dtype=jnp.float32)
print(f"reduced: {cfg.n_layers}L d={cfg.d_model} "
      f"params={cfg.param_count()/1e6:.1f}M")

# forward + loss
batch = model.example_batch(batch=2, seq=32, key=jax.random.PRNGKey(1),
                            dtype=jnp.float32)
logits, aux = model.forward(params, batch)
print(f"\nforward: logits {logits.shape}, loss "
      f"{float(model.loss(params, batch)):.3f}")

# prefill + decode (the serving path)
last, cache = model.prefill(params, batch, cache_len=48, dtype=jnp.float32)
tok = jnp.argmax(last, -1)[:, None].astype(jnp.int32)
for i in range(4):
    logits_step, cache = model.decode_step(params, tok, cache)
    tok = jnp.argmax(logits_step, -1)[:, None].astype(jnp.int32)
print(f"decoded 4 tokens, cache pos now {cache['pos']}")

# Chiron's local autoscaler (Algorithm 1) reacting to backpressure
scaler = LocalAutoscaler(itl_slo=0.2, init_batch=8)
print("\nAlgorithm 1 (batch-size autoscaling):")
for itl in (0.05, 0.05, 0.1, 0.25, 0.15):
    bs = scaler.update(LocalMetrics(observed_itl=itl, throughput=1000.0,
                                    itl_slo=0.2))
    print(f"  observed ITL {itl*1e3:4.0f}ms -> max batch size {bs}")
