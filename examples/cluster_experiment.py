"""Cluster-scale experiment: Chiron vs Llumnix on the W_B workload
(the paper's Fig. 19 / Appendix A.2 scenario), in the simulator.

  PYTHONPATH=src python examples/cluster_experiment.py
"""
from repro.serving.request import RequestType
from repro.sim.cluster import SimCluster
from repro.sim.controllers import ChironController, LlumnixController
from repro.sim.simulator import default_perf_factory, simulate
from repro.sim.workload import WorkloadSpec, generate

SPEC = dict(n_requests=2000, arrival_rate=30.0, interactive_frac=1.0,
            batch_queue_size=30000, batch_ttft_slo=1800.0,
            model="llama-8b", seed=5)


def run(name, ctrl):
    reqs = generate(WorkloadSpec(**SPEC))
    cluster = SimCluster(default_perf_factory(), max_chips=400)
    res = simulate(reqs, ctrl, cluster, max_time=2400, warm_start=2)
    s = res.summary()
    print(f"\n=== {name} ===")
    print(f"  SLO attainment: {100*s['slo_attainment']:.1f}% "
          f"(interactive {100*s['slo_interactive']:.1f}%, "
          f"batch {100*s['slo_batch']:.1f}%); completed "
          f"{100*s['completion_rate']:.1f}%")
    print(f"  per-instance throughput: {s['per_instance_throughput']:.0f} tok/s")
    print(f"  GPU hours: {s['gpu_hours']:.2f}  peak chips: {s['peak_chips']}")
    print(f"  scaling actions: {res.scale_ups} up / {res.scale_downs} down "
          f"(hysteresis {s['hysteresis']:.2f})")
    print("  chips over time:",
          " ".join(f"{p.chips}" for p in res.timeline[::len(res.timeline)//12 or 1]))
    return res


res_c = run("Chiron", ChironController(model="llama-8b"))
res_l = run("Llumnix", LlumnixController(model="llama-8b"))

save = 100 * (1 - res_c.gpu_hours() / max(res_l.gpu_hours(), 1e-9))
peak = 100 * (1 - res_c.peak_chips / max(res_l.peak_chips, 1))
print(f"\nChiron vs Llumnix: GPU-hour savings {save:.1f}%, "
      f"peak-GPU savings {peak:.1f}% (paper: up to 70%)")
print("Note: on a FINITE batch workload Chiron deliberately provisions the")
print("minimum cluster that meets the deadline (paper Fig. 19); its savings")
print("show up as peak GPUs (the paper's Fig. 2 metric) and as GPU-hours")
print("whenever interactive load shares the over-provisioned capacity.")
