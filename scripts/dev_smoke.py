"""Dev-time quick check: every assigned arch forward/prefill/decode on CPU."""
import sys

import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED_ARCHS, get_smoke_config
from repro.models import get_model

archs = sys.argv[1:] or ASSIGNED_ARCHS
for arch in archs:
    cfg = get_smoke_config(arch)
    model = get_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key, dtype=jnp.float32)
    B, S = 2, 64
    batch = model.example_batch(B, S, key, dtype=jnp.float32)
    logits, aux = model.forward(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size), (arch, logits.shape)
    assert not bool(jnp.any(jnp.isnan(logits))), f"{arch}: NaN in forward"
    loss = model.loss(params, batch)
    assert jnp.isfinite(loss), f"{arch}: non-finite loss"
    last, cache = model.prefill(params, batch, dtype=jnp.float32)
    assert last.shape == (B, cfg.vocab_size)
    tok = jnp.argmax(last, -1)[:, None].astype(jnp.int32)
    lg2, cache = model.decode_step(params, tok, cache)
    assert lg2.shape == (B, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(lg2))), f"{arch}: NaN in decode"
    # consistency: prefill last-token logits == forward last-position logits
    err = float(jnp.max(jnp.abs(last - logits[:, -1])))
    print(f"{arch:20s} ok  loss={float(loss):.3f}  prefill/fwd err={err:.2e}")
print("ALL OK")
