#!/usr/bin/env python
"""Cross-PR scenario-benchmark trend gate.

Diffs a freshly-generated ``BENCH_scenarios.json`` (written by
``benchmarks/scenario_sweep.py``) against the previously committed one and
**fails (exit 1) when any scenario's events/s regressed by more than the
threshold** (default 20%). The replay scenarios (``trace_replay``,
``million_replay``) are additionally gated on absolute **wall-clock**
(>20% slower fails) — they are the scale points the columnar hot path is
sized for, and events/s alone can mask a wall regression if the event
count drifts. The chaos scenarios (``zone_outage``, ``flash_crowd``)
carry recovery fields (``time_to_detect_s``, ``time_to_recover_s``,
``max_attainment_dip``) and are additionally gated on
**time-to-recover**: a run that takes >20% longer (beyond a one-bin
30 s jitter floor) to bring attainment back within epsilon of its
pre-shock baseline — or that stops recovering at all — fails. Rows
carrying a ``goodput`` field (all of them, now that the overload plane
stamps outcome rates) are gated on **goodput**: SLO-met completions/s
dropping by more than the threshold fails — the overload scenarios
(``retry_storm``, ``graceful_brownout``) exist precisely to keep that
number honest under saturation. New
scenarios (present only in the new file) and removed ones are reported
but never fail the gate; SLO/completion changes are surfaced for
eyeballs, not gated (they are workload properties, not perf).

Usage::

    python scripts/bench_trend.py                  # old = git HEAD's copy
    python scripts/bench_trend.py old.json new.json
    BENCH_TREND_THRESHOLD=0.3 python scripts/bench_trend.py
"""
import json
import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH = "BENCH_scenarios.json"


def _load_committed() -> dict:
    """The last committed BENCH_scenarios.json (git show HEAD:...)."""
    out = subprocess.run(["git", "show", f"HEAD:{BENCH}"], cwd=ROOT,
                         capture_output=True, text=True)
    if out.returncode != 0:
        raise SystemExit(f"bench_trend: no committed {BENCH} at HEAD "
                         f"({out.stderr.strip()}); pass two paths instead")
    return json.loads(out.stdout)


def _validate(doc, label: str) -> dict:
    """Schema check before gating: a malformed benchmark file must fail
    with a clear message, not a KeyError mid-diff. Returns ``doc``."""
    if not isinstance(doc, dict):
        raise SystemExit(f"bench_trend: {label}: expected a JSON object, "
                         f"got {type(doc).__name__}")
    rows = doc.get("scenarios")
    if not isinstance(rows, list) or not rows:
        raise SystemExit(f"bench_trend: {label}: missing or empty "
                         "'scenarios' list")
    for i, r in enumerate(rows):
        where = f"{label}: scenarios[{i}]"
        if not isinstance(r, dict):
            raise SystemExit(f"bench_trend: {where}: expected an object")
        if not isinstance(r.get("scenario"), str) or not r["scenario"]:
            raise SystemExit(f"bench_trend: {where}: 'scenario' must be a "
                             "non-empty string")
        if not isinstance(r.get("events_per_s"), (int, float)) \
                or isinstance(r.get("events_per_s"), bool):
            raise SystemExit(f"bench_trend: {where} "
                             f"({r['scenario']}): 'events_per_s' must be "
                             "a number")
        for k in ("wall_s", "slo_attainment", "completion_rate",
                  "telemetry_overhead_frac", "telemetry_events_per_s",
                  "time_to_detect_s", "time_to_recover_s",
                  "max_attainment_dip", "skipped_injections",
                  "goodput", "goodput_interactive", "reject_rate",
                  "shed_rate", "expired_rate"):
            v = r.get(k)
            if v is not None and (isinstance(v, bool)
                                  or not isinstance(v, (int, float))):
                raise SystemExit(f"bench_trend: {where} "
                                 f"({r['scenario']}): '{k}' must be a "
                                 "number when present")
    return doc


def _rows(doc: dict) -> dict:
    return {r["scenario"]: r for r in doc.get("scenarios", [])}


def main(argv) -> int:
    threshold = float(os.environ.get("BENCH_TREND_THRESHOLD", "0.2"))
    if len(argv) == 2:
        with open(argv[0]) as f:
            old = json.load(f)
        with open(argv[1]) as f:
            new = json.load(f)
        old_label, new_label = argv[0], argv[1]
    elif not argv:
        old = _load_committed()
        with open(os.path.join(ROOT, BENCH)) as f:
            new = json.load(f)
        old_label, new_label = f"HEAD:{BENCH}", BENCH
    else:
        print(__doc__)
        return 2
    _validate(old, old_label)
    _validate(new, new_label)

    old_rows, new_rows = _rows(old), _rows(new)
    failures = []
    print(f"{'scenario':28s} {'old ev/s':>10s} {'new ev/s':>10s} "
          f"{'delta':>8s}  note")
    for name in sorted(set(old_rows) | set(new_rows)):
        o, n = old_rows.get(name), new_rows.get(name)
        if o is None:
            print(f"{name:28s} {'-':>10s} {n['events_per_s']:10.0f} "
                  f"{'':>8s}  new scenario")
            continue
        if n is None:
            print(f"{name:28s} {o['events_per_s']:10.0f} {'-':>10s} "
                  f"{'':>8s}  removed")
            continue
        delta = n["events_per_s"] / max(o["events_per_s"], 1e-9) - 1.0
        note = ""
        if delta < -threshold:
            note = f"REGRESSION (> {threshold:.0%})"
            failures.append((name, delta))
        if name in ("trace_replay", "million_replay"):
            dwall = n.get("wall_s", 0.0) / max(o.get("wall_s", 0.0), 1e-9) \
                - 1.0
            if dwall > threshold:
                note += f" WALL REGRESSION ({dwall:+.1%})"
                failures.append((name, -dwall))
        # recovery gate (chaos scenarios): -1.0 means "never recovered",
        # 0.0 means "attainment never left the band" — both are valid
        # states, but old-recovered -> new-not-recovered always fails,
        # and a >threshold slowdown past a one-bin jitter floor fails
        o_ttr, n_ttr = o.get("time_to_recover_s"), n.get("time_to_recover_s")
        if o_ttr is not None and n_ttr is not None:
            if n_ttr < 0.0 and o_ttr >= 0.0:
                note += " RECOVERY REGRESSION (no longer recovers)"
                failures.append((name, -1.0))
            elif n_ttr >= 0.0 and o_ttr >= 0.0 \
                    and n_ttr > max(o_ttr * (1.0 + threshold),
                                    o_ttr + 30.0):
                dttr = n_ttr / max(o_ttr, 1e-9) - 1.0
                note += f" RECOVERY REGRESSION (ttr {o_ttr:.0f}s -> " \
                        f"{n_ttr:.0f}s)"
                failures.append((name, -dttr))
            elif n_ttr != o_ttr:
                note += f" ttr: {o_ttr} -> {n_ttr}"
        # goodput gate (overload scenarios): SLO-met completions/s is the
        # plane's currency — a >threshold drop means graceful degradation
        # stopped being graceful, and fails like a perf regression
        o_gp, n_gp = o.get("goodput"), n.get("goodput")
        if o_gp is not None and n_gp is not None and o_gp > 0:
            dgp = n_gp / o_gp - 1.0
            if dgp < -threshold:
                note += f" GOODPUT REGRESSION ({dgp:+.1%})"
                failures.append((name, dgp))
        for k in ("slo_attainment", "completion_rate", "goodput",
                  "shed_rate", "reject_rate"):
            if abs(n.get(k, 1.0) - o.get(k, 1.0)) > 1e-6:
                note += f" {k}: {o.get(k)} -> {n.get(k)}"
        print(f"{name:28s} {o['events_per_s']:10.0f} "
              f"{n['events_per_s']:10.0f} {delta:+8.1%}  {note}")

    if failures:
        print(f"\nbench_trend: FAIL — {len(failures)} scenario(s) regressed "
              f"past {threshold:.0%}: "
              + ", ".join(f"{n} ({d:+.1%})" for n, d in failures),
              file=sys.stderr)
        return 1
    print(f"\nbench_trend: ok ({len(new_rows)} scenarios, "
          f"threshold {threshold:.0%})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
