"""Dev-time kernel check: interpret-mode kernels vs oracles."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels import ops, ref

key = jax.random.PRNGKey(0)

# paged attention
B, n_kv, group, D = 4, 2, 4, 128
page, max_pages, num_pages = 16, 8, 64
ks = jax.random.split(key, 6)
q = jax.random.normal(ks[0], (B, n_kv, group, D), jnp.float32)
kp = jax.random.normal(ks[1], (num_pages, page, n_kv, D), jnp.float32)
vp = jax.random.normal(ks[2], (num_pages, page, n_kv, D), jnp.float32)
bt = jax.random.randint(ks[3], (B, max_pages), 0, num_pages, dtype=jnp.int32)
lengths = jnp.array([128, 37, 1, 100], jnp.int32)
out_k = ops.paged_attention(q, kp, vp, bt, lengths, backend="interpret")
out_r = ref.paged_attention_ref(q, kp, vp, bt, lengths)
np.testing.assert_allclose(out_k, out_r, atol=2e-5, rtol=2e-5)
print("paged_attention ok", float(jnp.max(jnp.abs(out_k - out_r))))

# flash prefill
B, H, Hkv, S, D = 2, 4, 2, 512, 128
q = jax.random.normal(ks[0], (B, H, S, D), jnp.float32)
k = jax.random.normal(ks[1], (B, Hkv, S, D), jnp.float32)
v = jax.random.normal(ks[2], (B, Hkv, S, D), jnp.float32)
out_k = ops.flash_prefill(q, k, v, block_q=128, block_k=128, backend="interpret")
out_r = ref.flash_prefill_ref(q, k, v)
np.testing.assert_allclose(out_k, out_r, atol=2e-5, rtol=2e-5)
print("flash_prefill ok", float(jnp.max(jnp.abs(out_k - out_r))))

# ssd scan: kernel vs chunked-model oracle vs sequential ground truth
b, s, h, p, n = 2, 256, 4, 64, 32
chunk = 64
x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h), jnp.float32))
A = -jnp.exp(jax.random.normal(ks[2], (h,), jnp.float32) * 0.5)
Bm = jax.random.normal(ks[3], (b, s, n), jnp.float32)
Cm = jax.random.normal(ks[4], (b, s, n), jnp.float32)
y_k, h_k = ops.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, backend="interpret")
y_r, h_r = ref.ssd_scan_ref(x, dt, A, Bm, Cm, chunk=chunk)
y_s, h_s = ref.ssd_sequential_ref(x, dt, A, Bm, Cm)
np.testing.assert_allclose(y_r, y_s, atol=1e-3, rtol=1e-3)
print("ssd chunked-model vs sequential ok", float(jnp.max(jnp.abs(y_r - y_s))))
np.testing.assert_allclose(y_k, y_r, atol=1e-3, rtol=1e-3)
np.testing.assert_allclose(h_k, h_r, atol=1e-3, rtol=1e-3)
print("ssd_scan kernel ok", float(jnp.max(jnp.abs(y_k - y_r))))
print("ALL KERNELS OK")
