"""Dev check: perf-model curves + a small Chiron-vs-Llumnix simulation."""
from repro.sim.perf_model import PerfModel
from repro.sim.workload import WorkloadSpec, generate
from repro.sim.cluster import SimCluster
from repro.sim.controllers import ChironController, LlumnixController
from repro.sim.simulator import default_perf_factory, simulate

# --- Fig 3 shape: ITL and throughput vs batch size
for model in ("llama-8b", "llama-70b"):
    pm = PerfModel(model)
    print(f"\n{model}: chips={pm.chips} params={pm.n_params/1e9:.1f}B "
          f"kv/tok={pm.kv_bytes_per_token()/1024:.0f}KiB "
          f"kv_cap={pm.kv_capacity_tokens()/1e3:.0f}k tok "
          f"load={pm.model_load_time():.0f}s")
    prev_thr = 0
    for b in (1, 8, 32, 64, 128, 256, 512, 1024):
        itl = pm.itl(b, 1024)
        thr = pm.throughput(b, 1024)
        print(f"  b={b:5d} itl={itl*1000:8.1f}ms thr={thr:8.0f} tok/s")
    print(f"  optimal batch @ITL 0.2s: {pm.optimal_batch(0.2, 1024)}, "
          f"@ITL 2s: {pm.optimal_batch(2.0, 1024)}")

# --- small interactive workload sim
spec = WorkloadSpec(n_requests=400, arrival_rate=20.0, model="llama-8b", seed=1)
reqs_c = generate(spec)
reqs_l = generate(spec)

cl = SimCluster(default_perf_factory(), max_chips=200)
ctrl = ChironController(model="llama-8b")
res_c = simulate(reqs_c, ctrl, cl, max_time=600, warm_start=2)
print("\nChiron:", {k: round(v, 3) for k, v in res_c.summary().items()})

cl2 = SimCluster(default_perf_factory(), max_chips=200)
ctrl2 = LlumnixController(model="llama-8b")
res_l = simulate(reqs_l, ctrl2, cl2, max_time=600, warm_start=2)
print("Llumnix:", {k: round(v, 3) for k, v in res_l.summary().items()})
