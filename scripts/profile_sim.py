#!/usr/bin/env python
"""Profile the simulator on any registered scenario.

cProfile the event core (or the fleet loop for fleet scenarios) over one
scenario build and print the top-N functions by tottime and cumtime —
the first tool to reach for before touching the hot path (see the
"profiling the simulator" walkthrough in tests/README.md).

Usage::

    python scripts/profile_sim.py                         # trace_replay
    python scripts/profile_sim.py diurnal
    python scripts/profile_sim.py trace_replay -n 100000
    python scripts/profile_sim.py burst_spikes --top 40 --sort cumulative
    python scripts/profile_sim.py multi_region --plain    # no profiler,
                                                          # wall + ev/s only
    python scripts/profile_sim.py trace_replay --phases   # per-phase wall

``--plain`` runs without instrumentation (cProfile inflates Python-call
costs ~2x, so confirm wall-clock wins un-instrumented).

``--phases`` threads a :class:`PhaseTimers` accumulator through the
event core's ``phase_timers`` hook: the loop brackets its six numbered
phases (arrivals, heap_drain, control, routing, sweep, sampling) with
cheap ``perf_counter`` laps and this prints the per-phase wall-clock
breakdown — phase attribution without cProfile's ~2x call-cost noise,
so the next perf PR starts from data. Per-lap overhead is two clock
reads; totals run ~5-10% above ``--plain`` wall.

``--json`` (with ``--plain`` or ``--phases``) replaces the human
report with one machine-readable JSON object on stdout — scenario,
wall_s, events, events_per_s, completion_rate, and (under
``--phases``) the per-phase seconds — for harnesses and the
phase-attribution smoke test (tests/test_profile_sim.py).
"""
from __future__ import annotations

import argparse
import cProfile
import io
import json
import os
import pstats
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__))), "src"))

from repro.sim.cluster import SimCluster                     # noqa: E402
from repro.sim.controllers import ChironController           # noqa: E402
from repro.sim.scenarios import SCENARIOS, build_trace       # noqa: E402
from repro.sim.simulator import (default_perf_factory,       # noqa: E402
                                 simulate_events, simulate_fleet)


class PhaseTimers:
    """Accumulating wall-clock buckets for the event loop's six phases.

    Implements the duck-typed protocol ``simulate_events`` /
    ``simulate_fleet`` expect from ``phase_timers``: ``clock()`` returns
    an opaque monotonic reading and ``lap(name, t0)`` folds
    ``clock() - t0`` into the named bucket and returns the new reading
    (so consecutive laps share one clock read). Wall-clock lives here in
    ``scripts/`` — the simulator itself stays deterministic (DET202)."""

    def __init__(self):
        self.buckets = {}
        self.clock = time.perf_counter

    def lap(self, name: str, t0: float) -> float:
        t1 = time.perf_counter()
        self.buckets[name] = self.buckets.get(name, 0.0) + (t1 - t0)
        return t1

    def report(self, wall: float) -> str:
        total = sum(self.buckets.values()) or 1e-12
        lines = ["  phase        seconds   of-loop  of-wall"]
        for name, secs in sorted(self.buckets.items(),
                                 key=lambda kv: -kv[1]):
            lines.append(f"  {name:<12} {secs:7.3f}   {secs / total:6.1%}"
                         f"   {secs / wall:6.1%}")
        lines.append(f"  {'(loop total)':<12} {total:7.3f}            "
                     f"{total / wall:7.1%}")
        return "\n".join(lines)


def run_scenario(name: str, n_requests: int, seed: int, max_chips: int,
                 phase_timers=None):
    trace, kw = build_trace(name, n_requests=n_requests, seed=seed)
    if "fleet" in kw:
        return simulate_fleet(trace, kw["fleet"](),
                              max_time=kw["max_time"], warm_start=1,
                              failures=kw.get("failures"),
                              degradations=kw.get("degradations"),
                              phase_timers=phase_timers)
    cluster = SimCluster(default_perf_factory(), max_chips=max_chips)
    ctrl = ChironController(models=kw["models"]) if "models" in kw \
        else ChironController()
    return simulate_events(trace, ctrl, cluster, max_time=kw["max_time"],
                           warm_start=2, failures=kw.get("failures"),
                           degradations=kw.get("degradations"),
                           phase_timers=phase_timers)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("scenario", nargs="?", default="trace_replay",
                    choices=sorted(SCENARIOS))
    ap.add_argument("-n", "--n-requests", type=int, default=0,
                    help="override the scenario's default request count")
    ap.add_argument("--seed", type=int, default=3)
    ap.add_argument("--max-chips", type=int, default=400)
    ap.add_argument("--top", type=int, default=25,
                    help="rows per pstats table")
    ap.add_argument("--sort", default="tottime",
                    choices=["tottime", "cumulative", "ncalls"])
    ap.add_argument("--plain", action="store_true",
                    help="no profiler: wall time + events/s only")
    ap.add_argument("--phases", action="store_true",
                    help="no profiler: per-phase wall-clock breakdown")
    ap.add_argument("--json", action="store_true",
                    help="with --plain/--phases: emit one JSON object "
                         "instead of the human report")
    args = ap.parse_args()

    if args.plain or args.phases or args.json:
        timers = PhaseTimers() if args.phases else None
        t0 = time.perf_counter()
        res = run_scenario(args.scenario, args.n_requests, args.seed,
                           args.max_chips, phase_timers=timers)
        wall = time.perf_counter() - t0
        if args.json:
            out = {
                "scenario": args.scenario,
                "wall_s": wall,
                "events": res.n_events,
                "events_per_s": res.n_events / wall,
                "completion_rate": res.completion_rate(),
            }
            if timers is not None:
                out["phases"] = dict(sorted(timers.buckets.items()))
            print(json.dumps(out))
            return 0
        print(f"{args.scenario}: {wall:.3f}s wall, {res.n_events} events, "
              f"{res.n_events / wall:,.0f} events/s, "
              f"completion={res.completion_rate():.4f}")
        if timers is not None:
            print(timers.report(wall))
        return 0

    pr = cProfile.Profile()
    t0 = time.perf_counter()
    pr.enable()
    res = run_scenario(args.scenario, args.n_requests, args.seed,
                       args.max_chips)
    pr.disable()
    wall = time.perf_counter() - t0
    print(f"{args.scenario}: {wall:.3f}s wall (profiled), "
          f"{res.n_events} events, {res.n_events / wall:,.0f} events/s")
    out = io.StringIO()
    pstats.Stats(pr, stream=out).sort_stats(args.sort).print_stats(args.top)
    print(out.getvalue())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
