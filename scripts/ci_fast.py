#!/usr/bin/env python
"""Fast CI loop: the non-JAX (sim / core / queue) test subset.

Runs the control-plane and simulator tests — everything that exercises
the autoscalers, the global queue, request groups, the waiting-time
estimator, and both simulation engines — without importing JAX-heavy
kernel/model modules. Target: well under a minute.

Usage:  python scripts/ci_fast.py [extra pytest args]
"""
import os
import subprocess
import sys
import time

FAST_TESTS = [
    "tests/test_autoscalers.py",
    "tests/test_configs.py",
    "tests/test_event_sim.py",
    "tests/test_fleet.py",           # multi-cluster placement/routing plane,
                                     # degradation, deterministic multi_region
    "tests/test_global_queue.py",
    "tests/test_ledger.py",          # columnar ledger + decision
                                     # equivalence vs the reference path
    "tests/test_request_groups.py",
    "tests/test_scenarios.py",       # scenario smoke incl. multi_model_fleet,
                                     # trace_replay, instance_failures
    "tests/test_simulator.py",
    "tests/test_system.py",
    "tests/test_trace_plane.py",     # columnar Trace + trace I/O + streaming
    "tests/test_waiting_time.py",
]


def main() -> int:
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    src = os.path.join(root, "src")
    env["PYTHONPATH"] = src + os.pathsep * bool(env.get("PYTHONPATH", "")) \
        + env.get("PYTHONPATH", "")
    cmd = [sys.executable, "-m", "pytest", "-q", *FAST_TESTS,
           *sys.argv[1:]]
    t0 = time.time()
    rc = subprocess.call(cmd, cwd=root, env=env)
    print(f"ci_fast: {time.time() - t0:.1f}s", file=sys.stderr)
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
