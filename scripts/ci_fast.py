#!/usr/bin/env python
"""Fast CI loop: static gates + the non-JAX (sim / core / queue) subset.

Three blocking stages, cheapest first:

1. ``python -m repro.analysis src`` — the invariant auditor (mirror-sync,
   determinism, hygiene rules). Zero findings or the build fails.
2. ``ruff check`` — when ruff is installed (see requirements-dev.txt);
   skipped with a notice otherwise (the auditor's LINT rules cover the
   same ground in-container).
3. The control-plane and simulator tests — everything that exercises the
   autoscalers, the global queue, request groups, the waiting-time
   estimator, and both simulation engines — without importing JAX-heavy
   kernel/model modules. Target: well under a minute.

Usage:  python scripts/ci_fast.py [extra pytest args]
"""
import os
import shutil
import subprocess
import sys
import time

FAST_TESTS = [
    "tests/test_analysis.py",        # invariant auditor rules + clean tree
    "tests/test_autoscalers.py",
    "tests/test_chaos.py",           # zone outages, flash crowds, noisy
                                     # detection, recovery metrics, tenants
    "tests/test_configs.py",
    "tests/test_event_sim.py",
    "tests/test_fleet.py",           # multi-cluster placement/routing plane,
                                     # degradation, deterministic multi_region
    "tests/test_global_queue.py",
    "tests/test_ledger.py",          # columnar ledger + decision
                                     # equivalence vs the reference path
    "tests/test_obs.py",             # flight recorder: replay equivalence,
                                     # span sampling, exporters, overhead
    "tests/test_overload.py",        # admission/shedding/retries/brownout/
                                     # breakers + attempt-column round trip
    "tests/test_profile_sim.py",     # profile harness --phases --json
                                     # contract
    "tests/test_queue_plane.py",     # columnar lane mechanics + reference
                                     # differential
    "tests/test_request_groups.py",
    "tests/test_scenarios.py",       # scenario smoke incl. multi_model_fleet,
                                     # trace_replay, instance_failures
    "tests/test_shadow_verify.py",   # runtime mirror audit + desync mutations
    "tests/test_simulator.py",
    "tests/test_system.py",
    "tests/test_trace_plane.py",     # columnar Trace + trace I/O + streaming
    "tests/test_waiting_time.py",
]


def main() -> int:
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    src = os.path.join(root, "src")
    env["PYTHONPATH"] = src + os.pathsep * bool(env.get("PYTHONPATH", "")) \
        + env.get("PYTHONPATH", "")
    t0 = time.time()

    rc = subprocess.call([sys.executable, "-m", "repro.analysis", "src"],
                         cwd=root, env=env)
    if rc != 0:
        print("ci_fast: repro.analysis found violations (see above)",
              file=sys.stderr)
        return rc

    ruff = shutil.which("ruff")
    if ruff:
        rc = subprocess.call([ruff, "check", "src", "tests", "scripts"],
                             cwd=root, env=env)
        if rc != 0:
            print("ci_fast: ruff check failed", file=sys.stderr)
            return rc
    else:
        print("ci_fast: ruff not installed — skipping (the repro.analysis "
              "LINT rules still gate)", file=sys.stderr)

    cmd = [sys.executable, "-m", "pytest", "-q", *FAST_TESTS,
           *sys.argv[1:]]
    rc = subprocess.call(cmd, cwd=root, env=env)
    print(f"ci_fast: {time.time() - t0:.1f}s", file=sys.stderr)
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
