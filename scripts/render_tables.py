"""Render EXPERIMENTS.md roofline tables from dry-run JSONL records."""
import json
import sys


def load(path):
    latest = {}
    with open(path) as f:
        for line in f:
            if line.strip():
                r = json.loads(line)
                latest[(r["arch"], r["shape"])] = r
    return latest


HBM_GIB = 16.0  # v5e


def table(recs, title):
    print(f"\n### {title}\n")
    print("| arch | shape | mesh | compute | memory | collective | "
          "bottleneck | useful | GiB/dev | fits HBM | status |")
    print("|---|---|---|---:|---:|---:|---|---:|---:|---|---|")
    for (a, s), r in sorted(recs.items()):
        if r["status"] != "ok":
            print(f"| {a} | {s} | {r['mesh']} | | | | | | | | FAIL |")
            continue
        gib = r["bytes_per_device"] / 2**30
        print(f"| {a} | {s} | {r['mesh']} "
              f"| {r['compute_s']*1e3:.2f} ms | {r['memory_s']*1e3:.2f} ms "
              f"| {r['collective_s']*1e3:.2f} ms | {r['bottleneck']} "
              f"| {r['useful_flops_ratio']:.2f} "
              f"| {gib:.2f} | {'yes' if gib <= HBM_GIB else 'NO'} | ok |")


def multipod_table(recs, title):
    print(f"\n### {title}\n")
    print("| arch | shape | mesh | GiB/dev | compile | status |")
    print("|---|---|---|---:|---:|---|")
    for (a, s), r in sorted(recs.items()):
        if r["status"] != "ok":
            print(f"| {a} | {s} | {r['mesh']} | | | FAIL |")
            continue
        print(f"| {a} | {s} | {r['mesh']} "
              f"| {r['bytes_per_device']/2**30:.2f} "
              f"| {r.get('compile_s', 0):.1f}s | ok |")


if __name__ == "__main__":
    mode = sys.argv[1] if len(sys.argv) > 1 else "roofline"
    path = sys.argv[2]
    title = sys.argv[3] if len(sys.argv) > 3 else path
    recs = load(path)
    if mode == "roofline":
        table(recs, title)
    else:
        multipod_table(recs, title)
