"""Dev check: real continuous-batching engine + local autoscaler loop."""
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.core.backpressure import LocalMetrics
from repro.core.local_autoscaler import LocalAutoscaler
from repro.serving.engine import Engine
from repro.serving.request import make_batch, make_interactive

cfg = get_smoke_config("granite-8b")
eng = Engine(cfg, max_slots=4, max_len=96, dtype=jnp.float32)

reqs = [make_interactive(16, 8), make_interactive(24, 12),
        make_batch(16, 20), make_batch(16, 20), make_batch(16, 6)]
for r in reqs:
    eng.submit(r)

scaler = LocalAutoscaler(itl_slo=0.5, init_batch=2, max_batch=4)
steps = 0
while (eng.waiting or eng.n_active) and steps < 200:
    stats = eng.step()
    steps += 1
    if steps % 5 == 0:
        bs = scaler.update(LocalMetrics(stats.itl, stats.throughput or 1.0, 0.5))
        eng.set_max_batch_size(bs)

fin = [r for r in reqs if r.state.value == "finished"]
print(f"steps={steps} finished={len(fin)}/{len(reqs)} "
      f"final_bs={scaler.max_batch_size}")
assert len(fin) == len(reqs), [r.state for r in reqs]
for r in reqs:
    assert r.tokens_generated >= r.output_len
    assert r.first_token_time is not None
print("preemptions:", [r.preemptions for r in reqs])
print("ENGINE OK")
